"""System-level property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.engine import Engine, EngineConfig
from repro.core.hotspot import merge_keys, split_keys
from repro.core.workflow import Workflow
from tests.conftest import (CountingUpdater, PassThroughMapper, VSPEC,
                            make_batch)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_engine_is_deterministic(seed):
    """Same inputs -> bit-identical slates and stats (the paper's
    well-definedness conditions, section 3)."""
    def run():
        wf = Workflow([PassThroughMapper(), CountingUpdater()],
                      external_streams=("S1",))
        eng = Engine(wf, EngineConfig(batch_size=32, queue_capacity=128))
        state = eng.init_state()
        rng = np.random.default_rng(seed)
        for t in range(5):
            keys = rng.integers(0, 30, size=24).astype(np.int32)
            xs = rng.integers(0, 9, size=24).astype(np.int32)
            state, _ = eng.step(state, {"S1": make_batch(keys, xs,
                                                         ts=[t] * 24)})
        t_ = state["tables"]["U1"]
        return (np.asarray(t_.keys).copy(),
                np.asarray(t_.vals["count"]).copy(),
                np.asarray(t_.vals["sum"]).copy())

    a, b = run(), run()
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=100),
       st.integers(2, 16))
def test_key_split_conserves_and_spreads(keys, ways):
    """Splitting is lossless (merge recovers the key) and per-event."""
    karr = jnp.asarray(keys, jnp.int32)
    ts = jnp.arange(len(keys), dtype=jnp.int32)
    split = split_keys(karr, ts, ways)
    back = merge_keys(split, ways)
    assert np.array_equal(np.asarray(back), np.asarray(karr))
    subs = np.asarray(split % ways)
    if len(set(keys)) == 1 and len(keys) >= 32:
        # a hot key's events hit several sub-keys
        assert len(np.unique(subs)) >= min(ways, 4) // 2


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 99)),
                min_size=1, max_size=60))
def test_event_conservation(pairs):
    """Every valid event is either processed into a slate count, still
    queued, or counted as dropped — none vanish."""
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=16, queue_capacity=32))
    state = eng.init_state()
    keys = [k for k, _ in pairs]
    xs = [x for _, x in pairs]
    state, _ = eng.step(state, {"S1": make_batch(keys, xs)})
    for t in range(12):
        state, _ = eng.step(state, {"S1": make_batch(
            [0], valid=[False], ts=[100 + t])})
    s = eng.stats(state)
    counted = sum(int(np.asarray(jax.device_get(
        state["tables"]["U1"].vals["count"]))[i])
        for i in range(512)
        if int(np.asarray(jax.device_get(
            state["tables"]["U1"].keys))[i]) != -1)
    dropped = sum(s["queue_dropped"].values()) + \
        sum(s["table_dropped"].values())
    queued = sum(s["queue_size"].values())
    assert counted + dropped + queued == len(pairs)
