"""System-level property tests (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.hotspot import merge_keys, split_keys
from repro.core.workflow import Workflow
from tests.conftest import (CountingUpdater, PassThroughMapper, VSPEC,
                            make_batch)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_engine_is_deterministic(seed):
    """Same inputs -> bit-identical slates and stats (the paper's
    well-definedness conditions, section 3)."""
    def run():
        wf = Workflow([PassThroughMapper(), CountingUpdater()],
                      external_streams=("S1",))
        eng = Engine(wf, EngineConfig(batch_size=32, queue_capacity=128))
        state = eng.init_state()
        rng = np.random.default_rng(seed)
        for t in range(5):
            keys = rng.integers(0, 30, size=24).astype(np.int32)
            xs = rng.integers(0, 9, size=24).astype(np.int32)
            state, _ = eng.step(state, {"S1": make_batch(keys, xs,
                                                         ts=[t] * 24)})
        t_ = state["tables"]["U1"]
        return (np.asarray(t_.keys).copy(),
                np.asarray(t_.vals["count"]).copy(),
                np.asarray(t_.vals["sum"]).copy())

    a, b = run(), run()
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=100),
       st.integers(2, 16))
def test_key_split_conserves_and_spreads(keys, ways):
    """Splitting is lossless (merge recovers the key) and per-event."""
    karr = jnp.asarray(keys, jnp.int32)
    ts = jnp.arange(len(keys), dtype=jnp.int32)
    split = split_keys(karr, ts, ways)
    back = merge_keys(split, ways)
    assert np.array_equal(np.asarray(back), np.asarray(karr))
    subs = np.asarray(split % ways)
    if len(set(keys)) == 1 and len(keys) >= 32:
        # a hot key's events hit several sub-keys
        assert len(np.unique(subs)) >= min(ways, 4) // 2


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 99)),
                min_size=1, max_size=60))
def test_event_conservation(pairs):
    """Every valid event is either processed into a slate count, still
    queued, or counted as dropped — none vanish."""
    wf = Workflow([PassThroughMapper(), CountingUpdater()],
                  external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=16, queue_capacity=32))
    state = eng.init_state()
    keys = [k for k, _ in pairs]
    xs = [x for _, x in pairs]
    state, _ = eng.step(state, {"S1": make_batch(keys, xs)})
    for t in range(12):
        state, _ = eng.step(state, {"S1": make_batch(
            [0], valid=[False], ts=[100 + t])})
    s = eng.stats(state)
    counted = sum(int(np.asarray(jax.device_get(
        state["tables"]["U1"].vals["count"]))[i])
        for i in range(512)
        if int(np.asarray(jax.device_get(
            state["tables"]["U1"].keys))[i]) != -1)
    dropped = sum(s["queue_dropped"].values()) + \
        sum(s["table_dropped"].values())
    queued = sum(s["queue_size"].values())
    assert counted + dropped + queued == len(pairs)


# ---------------------------------------------------------------------------
# durability primitives (DESIGN.md section 10)
# ---------------------------------------------------------------------------

def _wal_roundtrip(tick_batches, tmpdir):
    """Append arbitrary EventBatch pytrees, replay, compare exactly."""
    import os
    from repro.slates.wal import WriteAheadLog
    path = os.path.join(tmpdir, "w.log")
    if os.path.exists(path):
        os.remove(path)
    wal = WriteAheadLog(path)
    for t, batches in tick_batches:
        wal.append(t, batches)
    got = list(wal.replay())
    wal.close()
    assert [t for t, _ in got] == [t for t, _ in tick_batches]
    for (_, want), (_, have) in zip(tick_batches, got):
        assert sorted(want) == sorted(have)
        for s in want:
            for name in ("sid", "ts", "key", "valid"):
                w = np.asarray(getattr(want[s], name))
                h = np.asarray(getattr(have[s], name))
                assert w.dtype == h.dtype and w.tobytes() == h.tobytes()
            wl = jax.tree_util.tree_leaves_with_path(want[s].value)
            hl = dict(jax.tree_util.tree_leaves_with_path(have[s].value))
            assert len(wl) == len(hl)
            for pth, leaf in wl:
                h = np.asarray(hl[pth])
                w = np.asarray(leaf)
                assert w.dtype == h.dtype and w.shape == h.shape
                assert w.tobytes() == h.tobytes(), pth


def _batch_from(keys, xs, bits, valid):
    """Nested-pytree EventBatch: scalar int32 leaf + [B, 2] float32 leaf
    + a bool leaf, under nested dicts (the WAL must be schema-agnostic)."""
    b = len(keys)
    value = {
        "a": {"x": np.asarray(xs, np.int32)},
        "f": np.stack([np.asarray(xs, np.float32) * 0.5,
                       np.asarray(keys, np.float32)], axis=1),
        "flag": np.asarray(bits, bool),
    }
    return EventBatch.of(key=np.asarray(keys, np.int32), value=value,
                         ts=np.arange(b, dtype=np.int32),
                         valid=np.asarray(valid, bool))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(-2**31, 2**31 - 1),
                          st.integers(-2**31, 2**31 - 1),
                          st.booleans(), st.booleans()),
                min_size=1, max_size=32),
       st.integers(0, 100))
def test_wal_roundtrip_property(rows, t0, tmp_path_factory):
    """WAL append/replay is lossless over arbitrary EventBatch pytrees
    (keys, values, validity, dtypes — bit-exact)."""
    keys = [k for k, _, _, _ in rows]
    xs = [x for _, x, _, _ in rows]
    bits = [b for _, _, b, _ in rows]
    valid = [v for _, _, _, v in rows]
    batch = _batch_from(keys, xs, bits, valid)
    ticks = [(t0, {"S1": batch}), (t0 + 1, {"S1": batch, "S2": batch})]
    _wal_roundtrip(ticks, str(tmp_path_factory.mktemp("wal")))


def test_wal_roundtrip_example(tmp_path):
    """Example-based twin of the property (runs under the hypothesis
    stub too, so a clean checkout still exercises the round-trip)."""
    batch = _batch_from([1, -5, 2**31 - 1], [7, 0, -9],
                        [True, False, True], [True, True, False])
    _wal_roundtrip([(0, {"S1": batch}), (3, {"S1": batch, "S2": batch})],
                   str(tmp_path))


def _recover_once(snapshot, batches, table_in=None):
    """restore_into + replay through the associative path — the recovery
    primitive sequence."""
    from repro.core import apply as apply_mod
    from repro.slates import table as tbl
    from repro.slates.flush import restore_into
    from tests.conftest import CountingUpdater
    up = CountingUpdater()
    t = table_in if table_in is not None else tbl.make_table(
        128, up.slate_spec())
    keys, ts, vals = snapshot
    t = restore_into(t, keys, vals, ts)
    for i, b in enumerate(batches):
        t, _, _ = apply_mod.apply_associative(up, t, b, jnp.int32(i),
                                              impl="off")
    keys_arr = np.asarray(jax.device_get(t.keys))
    out = {}
    for i, k in enumerate(keys_arr):
        if k != -1:
            out[int(k)] = {lk: np.asarray(jax.device_get(lv))[i].item()
                           for lk, lv in t.vals.items()}
    return out, t


_EMPTY_SNAPSHOT = (np.zeros(0, np.int32), np.zeros(0, np.int32),
                   {"count": np.zeros(0, np.int32),
                    "sum": np.zeros(0, np.float32)})


def _check_recovery_exactly_once(pairs, split):
    """snapshot(prefix) + replay(suffix) == uninterrupted run, and a
    crash-during-recovery retry from the same snapshot is bit-identical
    (``restore_into`` overwrites, so replaying the prefix of the replay
    twice across two recovery attempts does not double-merge)."""
    from repro.slates.flush import dirty_snapshot
    keys = np.asarray([k for k, _ in pairs], np.int32)
    xs = np.asarray([x for _, x in pairs], np.int32)
    batches = [make_batch(keys, xs), make_batch((keys + 1) % 31, xs),
               make_batch((keys + 7) % 31, xs)]
    split = split % len(batches)

    full, _ = _recover_once(_EMPTY_SNAPSHOT, batches)
    # flush boundary after `split` batches: snapshot the dirty slates
    _, t_prefix = _recover_once(_EMPTY_SNAPSHOT, batches[:split])
    snap_keys, snap_ts, snap_vals, _ = dirty_snapshot(t_prefix)
    snapshot = (snap_keys, snap_ts, snap_vals)

    rec, _ = _recover_once(snapshot, batches[split:])
    assert full == rec
    # first recovery attempt dies mid-replay (partial table discarded);
    # the retry restores + replays from the same frontier: same slates
    _recover_once(snapshot, batches[split:split + 1])
    retry, _ = _recover_once(snapshot, batches[split:])
    assert retry == rec


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 99)),
                min_size=1, max_size=24),
       st.integers(0, 2))
def test_recovery_exactly_once_property(pairs, split):
    """The sum_mergeable exactly-once-by-merge contract at primitive
    level: restoring a flush snapshot and replaying the WAL suffix
    reproduces the uninterrupted slates, for any flush split point."""
    _check_recovery_exactly_once(pairs, split)


def test_recovery_exactly_once_example():
    for split in (0, 1, 2):
        _check_recovery_exactly_once([(0, 5), (0, 7), (3, 1), (9, 9)],
                                     split)
