"""The shard_map expert-parallel MoE path must match the global path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.context import Ctx
from repro.models.layers import moe


@pytest.mark.slow
def test_sharded_matches_global_1x1():
    cfg = reduced_config("deepseek-moe-16b")
    params, _ = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y0, aux0 = moe._apply_global(params, x, Ctx(cdtype=jnp.float32,
                                                phase="train"), cfg=cfg)
    mesh = make_host_mesh(n_data=1, n_model=1)
    rules = shd.rules_for(mesh, phase="train")
    ctx = Ctx(cdtype=jnp.float32, phase="train", mesh=mesh, rules=rules)
    assert moe._sharded_ok(cfg, ctx)
    with mesh:
        y1, aux1 = moe.apply(params, x, ctx, cfg=cfg)
    assert np.allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    assert abs(float(aux0) - float(aux1)) < 1e-7


@pytest.mark.slow
def test_sharded_moe_grads():
    cfg = reduced_config("deepseek-moe-16b")
    mesh = make_host_mesh(n_data=1, n_model=1)
    rules = shd.rules_for(mesh, phase="train")
    model = lm.build(cfg)
    params, _ = lm.init(model, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (2, 12), 0, cfg.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    ctx = Ctx(cdtype=jnp.float32, mesh=mesh, rules=rules)
    with mesh:
        loss, grads = jax.value_and_grad(
            lambda p: lm.train_loss(model, p, batch, ctx))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    assert all(np.any(np.asarray(g) != 0) for g in leaves)


def test_decode_uses_global_path():
    cfg = reduced_config("deepseek-moe-16b")
    mesh = make_host_mesh(n_data=1, n_model=1)
    rules = shd.rules_for(mesh, phase="decode")
    ctx = Ctx(cdtype=jnp.float32, phase="decode", mesh=mesh, rules=rules)
    assert not moe._sharded_ok(cfg, ctx)
