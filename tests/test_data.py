import numpy as np

from repro.data.synthetic import Prefetcher, TokenStream, ZipfEventSource


def test_token_stream_deterministic():
    a = next(iter(TokenStream(512, 4, 32, seed=7)))
    b = next(iter(TokenStream(512, 4, 32, seed=7)))
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])
    # labels are next tokens
    assert np.array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_token_stream_learnable_structure():
    """Markov structure: successor entropy is far below uniform."""
    s = TokenStream(256, 8, 128, seed=0, branching=4)
    batch = next(iter(s))
    toks, labs = batch["tokens"], batch["labels"]
    # count how often the label is one of the 4 designated successors
    hits = 0
    total = 0
    for b in range(8):
        for t in range(127):
            total += 1
            if labs[b, t] in s.succ[toks[b, t]]:
                hits += 1
    assert hits / total > 0.8    # 10% noise + collisions


def test_zipf_source_skew():
    src = ZipfEventSource(n_keys=10_000, alpha=1.2, seed=0,
                          events_per_tick=4096)
    b = src.next_batch()
    keys = np.asarray(b.key)
    top = np.bincount(keys, minlength=10_000).max()
    assert top > 4096 * 0.02     # head key way above uniform (0.01%)
    assert int(np.asarray(b.count())) == 4096


def test_zipf_source_throttle_arg():
    src = ZipfEventSource(events_per_tick=256)
    b = src.next_batch(max_events=64)
    assert int(np.asarray(b.count())) == 64


def test_prefetcher_order():
    pf = Prefetcher(iter(range(20)), depth=2)
    got = [next(pf) for _ in range(20)]
    assert got == list(range(20))
    pf.close()
