"""Shared benchmark workloads: the paper's counting application (Example
1/4) at benchmark scale, plus Zipf-skewed sources."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater)
from repro.core.workflow import Workflow

VSPEC = {"x": ((), jnp.float32)}


class SourceMapper(Mapper):
    name = "M1"
    subscribes = ("S1",)
    in_value_spec = VSPEC
    out_streams = {"S2": VSPEC}

    def map_batch(self, batch):
        return {"S2": EventBatch(sid=batch.sid, ts=batch.ts + 1,
                                 key=batch.key, value=batch.value,
                                 valid=batch.valid)}


class CounterUpdater(AssociativeUpdater):
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 1 << 16
    sum_mergeable = True   # counter: combine/merge are elementwise sums

    def slate_spec(self):
        return {"count": ((), jnp.int32), "sum": ((), jnp.float32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key),
                "sum": batch.value["x"]}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"],
                "sum": a["sum"] + b["sum"]}

    def merge(self, s, d):
        return {"count": s["count"] + d["count"],
                "sum": s["sum"] + d["sum"]}


class VecCounterUpdater(AssociativeUpdater):
    """Single [8]-vector slate leaf — the packed layout the Pallas
    point-lookup kernel accepts, so batched slate reads engage the
    kernel on TPU (jnp gather elsewhere; BENCH slate_read_*)."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 1 << 16
    sum_mergeable = True

    def slate_spec(self):
        return {"v": ((8,), jnp.float32)}

    def lift(self, batch):
        return {"v": jnp.broadcast_to(batch.value["x"][:, None],
                                      (batch.key.shape[0], 8))}

    def combine(self, a, b):
        return {"v": a["v"] + b["v"]}

    def merge(self, s, d):
        return {"v": s["v"] + d["v"]}


class SequentialCounter(SequentialUpdater):
    """Order-sensitive variant (EWMA) — exercises the padded-run path."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 1 << 16
    max_run = 16

    def slate_spec(self):
        return {"ewma": ((), jnp.float32), "n": ((), jnp.int32)}

    def step(self, slate, ev):
        return ({"ewma": 0.9 * slate["ewma"] + 0.1 * ev["value"]["x"],
                 "n": slate["n"] + 1}, {})


def counting_engine(batch_size=2048, queue_capacity=8192,
                    sequential=False, fused="auto", telemetry=None,
                    vec=False):
    upd = (SequentialCounter() if sequential else
           VecCounterUpdater() if vec else CounterUpdater())
    wf = Workflow([SourceMapper(), upd], external_streams=("S1",))
    eng = Engine(wf, EngineConfig(batch_size=batch_size,
                                  queue_capacity=queue_capacity,
                                  fused=fused, telemetry=telemetry))
    return eng, eng.init_state()


def chain_engine(n_mappers=3, batch_size=2048, queue_capacity=8192,
                 fuse=True):
    """A linear n-mapper chain ending in the counting updater, built
    via the declarative App layer so the planner's mapper fusion can be
    toggled (BENCH mapper_chain3_*)."""
    from repro.api import App

    app = App("chain_bench")
    app.source("S1", VSPEC)
    prev = "S1"
    for i in range(n_mappers):
        nxt = f"S{i + 2}"

        @app.mapper(prev, out=nxt, name=f"M{i + 1}")
        def hop(batch):
            return EventBatch(sid=batch.sid, ts=batch.ts + 1,
                              key=batch.key,
                              value={"x": batch.value["x"] + 1.0},
                              valid=batch.valid)
        prev = nxt
    app.add(CounterUpdater(), subscribes=(prev,))
    eng = Engine(app.build(fuse=fuse),
                 EngineConfig(batch_size=batch_size,
                              queue_capacity=queue_capacity))
    return eng, eng.init_state()


def zipf_batch(rng, n, n_keys=100_000, alpha=1.2, tick=0):
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    keys = rng.choice(n_keys, size=n, p=p).astype(np.int32)
    return EventBatch.of(key=keys,
                         value={"x": rng.normal(size=n)
                                .astype(np.float32)},
                         ts=np.full(n, tick, np.int32))


def uniform_batch(rng, n, n_keys=100_000, tick=0):
    keys = rng.integers(0, n_keys, size=n).astype(np.int32)
    return EventBatch.of(key=keys,
                         value={"x": rng.normal(size=n)
                                .astype(np.float32)},
                         ts=np.full(n, tick, np.int32))
