"""Roofline report: reads ``experiments/dryrun/*.json`` into the
EXPERIMENTS.md section-Roofline table and picks hillclimb candidates.

Terms (TPU v5e): compute = flops / 197e12, memory = hbm_bytes / 819e9,
collective = collective_bytes / 50e9 — all per chip per step, from the
HLO walker (while-loop trip counts included).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--md out.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(tag: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, *, multi_pod=False):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "peak GB/dev | useful-flops frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["multi_pod"] != multi_pod:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | "
                f"SKIP: {r['skip_reason'][:60]} |")
            continue
        t = r["roofline_terms_s"]
        uf = r.get("useful_flops_fraction")
        peak = r["memory_analysis"]["peak_estimate_bytes_per_device"] / 1e9
        dom = r["dominant_term"].replace("_s", "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{dom} | {peak:.1f} | "
            f"{uf:.2f} | |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{dom} | {peak:.1f} | - | |")
    return "\n".join(lines)


def hillclimb_candidates(recs):
    """worst roofline fraction, most collective-bound, most
    paper-representative (serving/decode — the slate-managed phase)."""
    ok = [r for r in recs if r["status"] == "ok" and not r["multi_pod"]]

    def frac(r):
        t = r["roofline_terms_s"]
        dom = max(t.values())
        return (r["model_flops_per_device"] / 197e12) / dom if dom else 0

    worst = min(ok, key=frac)
    coll = max(ok, key=lambda r:
               r["roofline_terms_s"]["collective_s"]
               / max(sum(r["roofline_terms_s"].values()), 1e-12))
    serving = [r for r in ok if r["shape"] in ("decode_32k", "long_500k")]
    rep = max(serving, key=lambda r: sum(r["roofline_terms_s"].values()))
    return {"worst_roofline_fraction": (worst, frac(worst)),
            "most_collective_bound": (coll, None),
            "paper_representative_serving": (rep, frac(rep))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.tag)
    if not recs:
        print("no dryrun records found — run repro.launch.dryrun --all")
        sys.exit(1)
    out = []
    out.append("### Single-pod (16,16) = 256 chips\n")
    out.append(table(recs, multi_pod=False))
    out.append("\n### Multi-pod (2,16,16) = 512 chips\n")
    out.append(table(recs, multi_pod=True))
    cands = hillclimb_candidates(recs)
    out.append("\n### Hillclimb candidates\n")
    for kind, (r, f) in cands.items():
        extra = f" (roofline fraction {f:.3f})" if f is not None else ""
        out.append(f"- **{kind}**: {r['arch']} x {r['shape']}{extra}; "
                   f"dominant={r['dominant_term']}")
    text = "\n".join(out)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
