"""Benchmark harness — one function per paper claim/figure (section 5).

Prints ``name,us_per_call,derived`` CSV rows.  The paper's own numbers
(anchors): ~100 M tweets + 1.5 M checkins/day on tens of machines
(~1.2 K events/s sustained), < 2 s end-to-end latency, > 30 M slates,
compressed slates in the KV store, Zipf-skewed keys.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import (chain_engine, counting_engine,
                                  uniform_batch, zipf_batch)

ROWS = []


def row(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def _time(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def _time_min(fn, n=10, warmup=3):
    """Best-of-n: robust against scheduler noise on shared machines —
    used where the measured quantity is dispatch overhead, which noise
    swamps long before it shows up in a mean."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


# ----------------------------------------------------------------------
# paper section 5: event throughput (100M tweets/day ~ 1157/s cluster avg)
# ----------------------------------------------------------------------

def bench_event_throughput():
    eng, state = counting_engine(batch_size=2048, queue_capacity=8192)
    rng = np.random.default_rng(0)
    batches = [zipf_batch(rng, 2048, tick=t) for t in range(8)]
    box = {"state": state, "i": 0}

    def step():
        b = batches[box["i"] % len(batches)]
        box["state"], _ = eng.step(box["state"], {"S1": b})
        box["i"] += 1

    us = _time(step, n=30)
    ev_s = 2048 / (us / 1e6)
    row("throughput_associative_events", us,
        f"{ev_s:.0f} events/s/chip (paper cluster avg ~1.2e3/s)")


def bench_sequential_throughput():
    eng, state = counting_engine(batch_size=1024, queue_capacity=8192,
                                 sequential=True)
    rng = np.random.default_rng(0)
    batches = [uniform_batch(rng, 1024, tick=t) for t in range(8)]
    box = {"state": state, "i": 0}

    def step():
        b = batches[box["i"] % len(batches)]
        box["state"], _ = eng.step(box["state"], {"S1": b})
        box["i"] += 1

    us = _time(step, n=15)
    row("throughput_sequential_events", us,
        f"{1024/(us/1e6):.0f} events/s/chip (padded-run path)")


# ----------------------------------------------------------------------
# dispatch granularity: per-tick host dispatch vs device-resident scan
# (the hot-loop overhead Muppet pays per event batch; DESIGN.md 2.2)
# ----------------------------------------------------------------------

def bench_chunked_vs_pertick():
    from repro.core.engine import stack_sources
    n_ticks, bs = 32, 64
    rng = np.random.default_rng(6)
    batches = [zipf_batch(rng, bs, tick=t) for t in range(n_ticks)]

    eng, state = counting_engine(batch_size=bs, queue_capacity=4 * bs)
    box = {"s": state}

    def per_tick():
        st = box["s"]
        for b in batches:
            st, _ = eng.step(st, {"S1": b})
            _ = int(st["throttle_hits"])     # run()'s per-tick sync
        box["s"] = st

    us_seq = _time_min(per_tick) / n_ticks
    row("tick_dispatch_per_tick", us_seq,
        "one jitted tick + one device sync per host call")

    eng2, state2 = counting_engine(batch_size=bs, queue_capacity=4 * bs)
    stacked = stack_sources([{"S1": b} for b in batches])
    box2 = {"s": state2}

    def chunked():
        st, _, info = eng2.run_chunk(box2["s"], stacked)
        _ = np.asarray(info["throttle_hits"])   # one sync per chunk
        box2["s"] = st

    us_chunk = _time_min(chunked) / n_ticks
    row("tick_dispatch_chunked32", us_chunk,
        f"lax.scan over 32 ticks: {us_seq / us_chunk:.1f}x lower us/tick "
        f"than per-tick dispatch (target >= 2x)")


# ----------------------------------------------------------------------
# fused slate update: generic scan/gather/merge/scatter vs the packed
# slate_update path (Pallas on TPU; jnp backends exercised here)
# ----------------------------------------------------------------------

def bench_fused_slate_update():
    rng = np.random.default_rng(7)
    batches = [zipf_batch(rng, 2048, tick=t) for t in range(8)]
    baseline = None
    for impl in ("off", "jnp", "ref"):
        eng, state = counting_engine(batch_size=2048,
                                     queue_capacity=8192, fused=impl)
        box = {"s": state, "i": 0}

        def step():
            b = batches[box["i"] % len(batches)]
            box["s"], _ = eng.step(box["s"], {"S1": b})
            box["i"] += 1
            jax.block_until_ready(box["s"]["tick"])   # measure execution,
                                                      # not async dispatch

        us = _time(step, n=20)
        if impl == "off":
            baseline = us
            row("slate_update_generic", us,
                "associative scan + gather/merge/scatter (jnp path)")
        else:
            row(f"slate_update_fused_{impl}", us,
                f"{baseline / us:.2f}x vs generic; Pallas kernel engages "
                f"on TPU (validated in tests via interpret)")


# ----------------------------------------------------------------------
# planner mapper fusion: a 3-mapper linear chain as 3 queue hops vs one
# fused jitted stage (DESIGN.md 11.2; the api-layer dispatch win)
# ----------------------------------------------------------------------

def bench_fused_mapper_chain():
    rng = np.random.default_rng(9)
    batches = [zipf_batch(rng, 512, tick=t) for t in range(8)]
    baseline = None
    for fuse in (False, True):
        eng, state = chain_engine(n_mappers=3, batch_size=512,
                                  queue_capacity=2048, fuse=fuse)
        box = {"s": state, "i": 0}

        def step():
            b = batches[box["i"] % len(batches)]
            box["s"], _ = eng.step(box["s"], {"S1": b})
            box["i"] += 1
            jax.block_until_ready(box["s"]["tick"])

        us = _time_min(step, n=20)
        if not fuse:
            baseline = us
            row("mapper_chain3_unfused", us,
                "3 mapper queue hops + updater per tick (builder, "
                "fuse=False)")
        else:
            n_ops = len(eng.wf.operators)
            row("mapper_chain3_fused", us,
                f"planner-fused to {n_ops} ops: {baseline / us:.2f}x vs "
                f"unfused per tick (target >= 1x; latency also drops "
                f"3 hops -> 1)")


# ----------------------------------------------------------------------
# latency: < 2 s end-to-end (paper) -> per-hop tick latency here
# ----------------------------------------------------------------------

def bench_latency():
    eng, state = counting_engine(batch_size=256, queue_capacity=2048)
    rng = np.random.default_rng(1)
    b = zipf_batch(rng, 256)
    box = {"state": state}

    def block():  # 10 ticks per sample: amortizes the timer, and the
        for _ in range(10):  # block min rides out scheduler company
            box["state"], _ = eng.step(box["state"], {"S1": b})

    us = _time_min(block, n=8, warmup=2) / 10
    depth = 2  # map hop + update hop
    row("latency_per_tick", us,
        f"end-to-end {depth} hops = {depth*us/1e3:.2f} ms "
        f"(paper: < 2000 ms)")


def bench_latency_breakdown():
    """Decompose the durable tick's write path (DESIGN.md section 17):
    what still sits on the dispatch critical path after pipelining —
    the jitted tick itself, flush-row packing, the async-WAL hand-off,
    and the telemetry boundary *begin* — so regressions show up as the
    component that moved, not just a fatter latency_per_tick.  Runs
    after bench_durability so the wal row can be quoted against the
    synchronous wal_append_per_tick it displaced."""
    from repro.core.durability import DurabilityConfig
    from repro.core.engine import Engine, EngineConfig
    from repro.core.packing import pack, pack_spec
    from repro.core.workflow import Workflow
    from repro.slates.flush import FlushConfig, FlushPolicy
    from repro.telemetry.metrics import TelemetryConfig
    from benchmarks.workloads import CounterUpdater, SourceMapper

    rng = np.random.default_rng(15)
    b = zipf_batch(rng, 256)

    # dispatch: the jitted tick's execution (the floor everything else
    # is measured against)
    eng, state = counting_engine(batch_size=256, queue_capacity=2048)
    box = {"s": state}

    def step():
        box["s"], _ = eng.step(box["s"], {"S1": b})
        jax.block_until_ready(box["s"]["tick"])

    us_d = _time(step, n=50)
    row("latency_breakdown_dispatch", us_d,
        "jitted tick execution (map hop + update hop, 256 events)")

    # packing: the flush snapshot's device-side row transform (pack a
    # 512-slot two-leaf slate tree into its [C, d] buffer)
    spec = pack_spec({"count": ((), jnp.int32), "sum": ((), jnp.float32)})
    tree = {"count": jnp.ones((512,), jnp.int32),
            "sum": jnp.ones((512,), jnp.float32)}
    jax.block_until_ready(pack(tree, spec))
    us_p = _time_min(lambda: jax.block_until_ready(pack(tree, spec)),
                     n=30)
    row("latency_breakdown_packing", us_p,
        "flush-row pack of a 512-slot slate tree (chunk-boundary cost)")

    # wal: what durable logging costs the dispatch path now — one
    # bounded-queue hand-off; the writer drains during device compute
    # and the epoch fence settles it at the flush boundary
    sync_us = next((u for n, u, _ in ROWS if n == "wal_append_per_tick"),
                   None)
    with tempfile.TemporaryDirectory() as d:
        wf = Workflow([SourceMapper(), CounterUpdater()],
                      external_streams=("S1",))
        de = Engine(wf, EngineConfig(
            batch_size=256, queue_capacity=2048,
            durability=DurabilityConfig(
                dir=d, flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                         every_k=8))))
        tick_box = {"t": 0}

        def enq():
            de.dur.append(tick_box["t"], {"S1": b})
            tick_box["t"] += 1

        us_w = _time_min(enq, n=30)
        de.dur.fence()
        de.close()
    vs = f"; sync append was {sync_us:.0f}us" if sync_us else ""
    row("latency_breakdown_wal", us_w,
        f"async WAL hand-off on the dispatch path{vs} — the fence, not "
        f"the tick, pays the write")

    # telemetry: the boundary's critical-path half (tree copy + async
    # device->host start); the blocking device_get half overlaps the
    # next chunk (one-chunk report lag)
    tel_eng, tel_state = counting_engine(
        batch_size=256, queue_capacity=2048,
        telemetry=TelemetryConfig(impl="ref"))
    for t in range(4):
        tel_state, _ = tel_eng.step(tel_state, {"S1": b})
    jax.block_until_ready(tel_state["tick"])
    reg = tel_eng.telemetry
    us_sync = _time(lambda: reg.observe(tel_eng, tel_state), n=20)
    us_t = _time(lambda: reg.begin_observe(tel_eng, tel_state), n=20)
    row("latency_breakdown_telemetry", us_t,
        f"begin_observe (copy + async transfer start) on the dispatch "
        f"path; blocking observe is {us_sync:.0f}us, overlapped by the "
        f"next chunk")


# ----------------------------------------------------------------------
# hotspot: Zipf skew with/without key splitting (Example 6)
# ----------------------------------------------------------------------

def bench_hotspot_key_splitting():
    from repro.core.engine import Engine, EngineConfig
    from repro.core.hotspot import KeySplitMapper
    from repro.core.workflow import Workflow
    from benchmarks.workloads import SequentialCounter, SourceMapper, VSPEC

    rng = np.random.default_rng(2)
    hot = np.zeros(2048, np.int32)          # one pathological key
    def feed(eng, state, n_ticks=6):
        from repro.core.event import EventBatch
        deferred_total = 0
        for t in range(n_ticks):
            b = EventBatch.of(key=hot, value={"x": np.ones(2048,
                                                           np.float32)},
                              ts=np.full(2048, t, np.int32))
            state, _ = eng.step(state, {"S1": b})
        return eng.stats(state)

    wf_naive = Workflow([SourceMapper(), SequentialCounter()],
                        external_streams=("S1",))
    eng_n = Engine(wf_naive, EngineConfig(batch_size=2048,
                                          queue_capacity=1 << 15))
    t0 = time.perf_counter()
    stats_n = feed(eng_n, eng_n.init_state())
    t_naive = time.perf_counter() - t0

    split = KeySplitMapper("S1b", "S2", VSPEC, ways=64, name="M1")
    wf_split = Workflow([split, SequentialCounter()],
                        external_streams=("S1b",))
    eng_s = Engine(wf_split, EngineConfig(batch_size=2048,
                                          queue_capacity=1 << 15))

    def feed_split(eng, state, n_ticks=6):
        from repro.core.event import EventBatch
        for t in range(n_ticks):
            b = EventBatch.of(key=hot, value={"x": np.ones(2048,
                                                           np.float32)},
                              ts=np.full(2048, t, np.int32))
            state, _ = eng.step(state, {"S1b": b})
        return eng.stats(state)

    t0 = time.perf_counter()
    stats_s = feed_split(eng_s, eng_s.init_state())
    t_split = time.perf_counter() - t0

    backlog_naive = stats_n["queue_size"]["U1"]
    backlog_split = stats_s["queue_size"]["U1"]
    row("hotspot_key_split_64way", t_split / 6 * 1e6,
        f"hot-key backlog {backlog_naive} -> {backlog_split} events "
        f"(max_run bound; paper Example 6)")


# ----------------------------------------------------------------------
# high-QPS slate reads (DESIGN.md section 15): one batched device
# dispatch for a [Q] key vector vs Q looped host reads, plus the
# telemetry-admitted hot-key cache hit path
# ----------------------------------------------------------------------

def bench_slate_read():
    from repro.core.engine import StateHandle
    from repro.slates.replica import HotKeyCache

    eng, state = counting_engine(batch_size=2048, queue_capacity=8192,
                                 vec=True)
    rng = np.random.default_rng(10)
    for t in range(8):
        state, _ = eng.step(state, {"S1": zipf_batch(rng, 2048, tick=t)})
    jax.block_until_ready(state["tick"])

    Q = 1024
    keys = [int(k) for k in np.asarray(zipf_batch(rng, Q).key)]
    # the read mix the write path produced: Zipf-hot keys mostly
    # present, tail keys often missing

    def looped():
        for k in keys:
            eng.read_slate(state, "U1", k)

    us_loop = _time(looped, n=3, warmup=1)
    row("slate_read_looped_1024", us_loop,
        f"{Q} read_slate calls: one lookup dispatch + host sync each")

    def batched():
        eng.read_slates(state, "U1", keys)

    us_b = _time(batched, n=20)
    row("slate_read_qps", us_b,
        f"{Q/(us_b/1e6):.2e} reads/s: one fused lookup dispatch for "
        f"Q={Q}; {us_loop/us_b:.0f}x vs looped (target >= 10x); Pallas "
        f"kernel engages on TPU")

    lats = []
    for _ in range(50):
        t0 = time.perf_counter()
        batched()
        lats.append(time.perf_counter() - t0)
    row("slate_read_p99", float(np.percentile(lats, 99)) * 1e6,
        f"p99 over 50 batched Q={Q} reads "
        f"(median {float(np.median(lats))*1e6:.0f}us)")

    cache = HotKeyCache(capacity=256, ttl_s=60.0)
    cache.warm(keys[:16])
    h = StateHandle(eng, state, cache=cache)
    h.read_slate("U1", keys[0])          # admit + populate
    us_hit = _time_min(lambda: h.read_slate("U1", keys[0]), n=30)
    row("slate_read_cache_hit", us_hit,
        f"HotKeyCache hit: no device touch "
        f"({us_b/Q/us_hit:.1f}x vs amortized batched read)")


# ----------------------------------------------------------------------
# slate store: compression + read/write (paper: 2B slates, compressed)
# ----------------------------------------------------------------------

def bench_slate_store():
    from repro.slates.kvstore import KVStore
    with tempfile.TemporaryDirectory() as d:
        store = KVStore(os.path.join(d, "kv"), replicas=3,
                        write_quorum=2, read_quorum=2)
        rng = np.random.default_rng(3)
        slate = {"counts": rng.integers(0, 5, 256).astype(np.int32)}

        def put():
            for k in range(64):
                store.put("U1", int(rng.integers(0, 1 << 20)), slate,
                          ts=0)
            store.flush()

        us = _time(put, n=5, warmup=1)
        row("kvstore_put64_quorum2", us,
            f"{64/(us/1e6):.0f} slate writes/s")

        store.put("U1", 777, slate, ts=0)

        def get():
            store.get("U1", 777)

        us_g = _time(get, n=30)
        row("kvstore_quorum_read", us_g, "read-through on cache miss")

        raw = 256 * 4
        from repro.slates import _compress
        comp = len(_compress.Compressor(3).compress(
            slate["counts"].tobytes()))
        codec = "zstd" if _compress.HAVE_ZSTD else "zlib"
        row("slate_compression", 0.0,
            f"{raw}B -> {comp}B ({raw/comp:.1f}x {codec}; paper "
            f"compresses slates before Cassandra)")


# ----------------------------------------------------------------------
# failure handling: ring rebuild + reroute cost (paper 4.3)
# ----------------------------------------------------------------------

def bench_failover():
    from repro.core.hashing import HashRing, route
    ring = HashRing(256)
    keys = jnp.arange(1 << 16, dtype=jnp.int32)

    def reroute():
        ring.alive[:] = True
        ring.fail(17)
        rh, rs = ring.table()
        route(keys, 1, rh, rs).block_until_ready()

    us = _time(reroute, n=10)
    row("failover_ring_rebuild_256shards", us,
        "master broadcast + 64k-key reroute (no recompile)")


# ----------------------------------------------------------------------
# live elasticity (DESIGN.md section 12): runs in a subprocess with 16
# forced host devices so the main bench process keeps the real device
# ----------------------------------------------------------------------

_ELASTIC_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater
from repro.core.workflow import Workflow
from repro.core.distributed import DistributedEngine, DistConfig, _salt

VSPEC = {'x': ((), jnp.float32)}

class Counter(AssociativeUpdater):
    name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
    out_streams = {}; table_capacity = 1 << 13
    def slate_spec(self): return {'count': ((), jnp.int32)}
    def lift(self, b): return {'count': jnp.ones_like(b.key)}
    def combine(self, a, b): return {'count': a['count'] + b['count']}
    def merge(self, s, d): return {'count': s['count'] + d['count']}

def gb(keys, t, n_sh):
    k = keys.reshape(n_sh, -1)
    return EventBatch(sid=jnp.zeros(k.shape, jnp.int32),
                      ts=jnp.full(k.shape, t, jnp.int32),
                      key=jnp.asarray(k),
                      value={'x': jnp.ones(k.shape, jnp.float32)},
                      valid=jnp.ones(k.shape, bool))

def build(n, **kw):
    mesh = Mesh(np.array(jax.devices()[:n]), ('data',))
    wf = Workflow([Counter()], external_streams=('S1',))
    eng = DistributedEngine(wf, mesh, DistConfig(
        batch_size=256, queue_capacity=2048, **kw))
    return eng, eng.init_state()

# elastic_scale_8to16_host: PHYSICAL grow (8-slot mesh -> 16 slots) —
# the shape-change tier: device_get + host remap + recompile + step
eng, state = build(8)
rng = np.random.default_rng(0)
for t in range(8):
    state, _ = eng.step(state, {'S1': gb(
        rng.integers(0, 1 << 14, 2048).astype(np.int32), t, 8)})
rows = int(jax.device_get((state['tables']['U1'].keys != -1).sum()))
t0 = time.perf_counter()
state, rep = eng.scale(state, 16)
state, _ = eng.step(state, {'S1': gb(
    rng.integers(0, 1 << 14, 2048).astype(np.int32), 8, 16)})
jax.block_until_ready(state['tick'])
us = (time.perf_counter() - t0) * 1e6
print(f"HOST,{us:.2f},{rows},{sum(rep.moved_rows.values())}")
del eng, state

# elastic_scale_8to16 (device tier, DESIGN.md 14.1): pre-provisioned
# 16-slot mesh with 8 active — activation is a content-only ring swap,
# rows move via on-device all_to_all, nothing recompiles.  One warm
# grow/shrink cycle compiles the plan + migrate kernels (the cycle is
# bitwise state-neutral, so the timed run sees identical mover counts
# and hits the same jit bucket).
eng, state = build(16)
state, _ = eng.remove_shards(state, range(8, 16))
rng = np.random.default_rng(0)
for t in range(8):
    state, _ = eng.step(state, {'S1': gb(
        rng.integers(0, 1 << 14, 2048).astype(np.int32), t, 16)})
rows = int(jax.device_get((state['tables']['U1'].keys != -1).sum()))
state, _ = eng.scale(state, 16)                  # warm (compiles)
state, _ = eng.remove_shards(state, range(8, 16))
t0 = time.perf_counter()
state, rep = eng.scale(state, 16)
state, _ = eng.step(state, {'S1': gb(
    rng.integers(0, 1 << 14, 2048).astype(np.int32), 8, 16)})
jax.block_until_ready(state['tick'])
us = (time.perf_counter() - t0) * 1e6
assert rep.path == 'device', rep.path
print(f"DEVICE,{us:.2f},{rows},{sum(rep.moved_rows.values())},"
      f"{rep.pause_s:.6f},{rep.bytes_moved}")

# elastic_shrink_16to8: planned mass leave on the device tier (50%
# dead stays under the compaction threshold; slates leave the parked
# slots but the mesh keeps its shape).  Warm the shrink at current
# contents first so the timed run is compile-free.
state, _ = eng.remove_shards(state, range(8, 16))   # warm shrink
state, _ = eng.scale(state, 16)
t0 = time.perf_counter()
state, rep2 = eng.remove_shards(state, range(8, 16))
state, _ = eng.step(state, {'S1': gb(
    rng.integers(0, 1 << 14, 2048).astype(np.int32), 9, 16)})
jax.block_until_ready(state['tick'])
us2 = (time.perf_counter() - t0) * 1e6
assert rep2.path == 'device', rep2.path
print(f"SHRINK,{us2:.2f},{sum(rep2.moved_rows.values())},"
      f"{rep2.pause_s:.6f}")

# rebalance_hot_ring: load-aware reweight + migration, content-only
# ring swap (no recompile) + next step
eng2, state2 = build(8, exchange_slack=8.0)
hot = np.full(2048, 7, np.int32)
for t in range(8):
    state2, _ = eng2.step(state2, {'S1': gb(hot, t, 8)})
t0 = time.perf_counter()
state2, rep2 = eng2.rebalance(state2)
state2, _ = eng2.step(state2, {'S1': gb(hot, 8, 8)})
jax.block_until_ready(state2['tick'])
us2 = (time.perf_counter() - t0) * 1e6
hot_owner = int(eng2.ring.owners(np.array([7], np.int32),
                                 _salt('U1'))[0])
counts = eng2.ring.vnode_counts()
print(f"REBALANCE,{us2:.2f},{counts[hot_owner]},{counts.sum()}")
"""


def bench_elasticity():
    import subprocess
    root = os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run(
        [sys.executable, "-c", _ELASTIC_CODE], capture_output=True,
        text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
    if r.returncode != 0:      # pragma: no cover - surfacing CI breakage
        raise RuntimeError(f"elasticity bench failed:\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("HOST,"):
            _, us, rows, moved = line.split(",")
            row("elastic_scale_8to16_host", float(us),
                f"physical grow 8->16 slots: drain + host remap "
                f"{moved} of {rows} rows + recompile+step (the "
                f"shape-change tier)")
        elif line.startswith("DEVICE,"):
            _, us, rows, moved, pause, nbytes = line.split(",")
            row("elastic_scale_8to16", float(us),
                f"device tier: activate 8->16 on a 16-slot mesh, "
                f"all_to_all {moved} of {rows} rows "
                f"({int(nbytes)} B), no recompile; loss-free")
            p = float(pause)
            row("migration_rows_per_s", p * 1e6,
                f"{int(moved)/p:.2e} rows/s through the device "
                f"migration kernel (pause {p*1e3:.1f} ms)")
        elif line.startswith("SHRINK,"):
            _, us, moved, pause = line.split(",")
            row("elastic_shrink_16to8", float(us),
                f"device tier: planned leave 16->8 active, all_to_all "
                f"{moved} rows off the parked slots + step "
                f"(pause {float(pause)*1e3:.1f} ms)")
        elif line.startswith("REBALANCE,"):
            _, us, vn, budget = line.split(",")
            row("rebalance_hot_ring", float(us),
                f"load-aware reweight: hot shard down to {vn}/{budget} "
                f"vnodes, ring swap without recompilation")


# ----------------------------------------------------------------------
# telemetry (DESIGN.md section 13): sketch-on tick overhead + the
# closed loop (square-wave load -> shard count trace, subprocess)
# ----------------------------------------------------------------------

def _paired_delta(c_off, c_on, T, rounds=50):
    """Median of paired on-off chunk deltas, pair order alternating:
    adjacent pairs cancel slow drift, alternation cancels position
    bias — best-of-n does neither.  Returns us per tick."""
    deltas = []
    for i in range(rounds):
        first, second = (c_off, c_on) if i % 2 == 0 else (c_on, c_off)
        t0 = time.perf_counter()
        first()
        t1 = time.perf_counter()
        second()
        d = (time.perf_counter() - t1) - (t1 - t0)
        deltas.append(d if i % 2 == 0 else -d)
    return max(0.0, float(np.median(deltas)) * 1e6 / T)


def _chunk_stepper(stacked, tc):
    eng, state = counting_engine(batch_size=256, queue_capacity=2048,
                                 telemetry=tc)
    box = {"s": state}

    def chunk():
        box["s"], _, _ = eng.run_chunk(box["s"], stacked)
        jax.block_until_ready(box["s"]["tick"])

    for _ in range(3):
        chunk()
    return chunk


def bench_telemetry_overhead():
    """Added per-tick cost of the sketch, measured on the chunk path
    (32 scanned ticks amortize dispatch noise 32x) with the on/off
    timings interleaved — separately-constructed engines drift by more
    than the quantity under measurement otherwise.  Latency histograms
    stay off on both sides so only the sketch moves (they get their
    own row below)."""
    from repro.core.engine import stack_sources
    from repro.telemetry.metrics import TelemetryConfig
    lat = next((u for n, u, _ in ROWS if n == "latency_per_tick"), None)
    rng = np.random.default_rng(11)
    T = 32
    stacked = stack_sources([{"S1": zipf_batch(rng, 256, tick=t)}
                             for t in range(T)])
    c_off = _chunk_stepper(stacked, None)
    c_on = _chunk_stepper(stacked, TelemetryConfig(impl="ref",
                                                   latency_buckets=0))
    delta = _paired_delta(c_off, c_on, T)
    pct = f"{100 * delta / lat:.1f}% of latency_per_tick" if lat else "?"
    row("countmin_update_overhead", delta,
        f"count-min sketch in the jitted chunk tick: +{delta:.1f}us "
        f"({pct}; target <= 5%)")


def bench_histogram_overhead():
    """Added per-tick cost of the device latency histograms (DESIGN.md
    18): telemetry-on engines with and without ``latency_buckets``,
    same interleaved paired-delta protocol as the sketch row so only
    the per-arc histogram update moves.  Budget-guarded in CI
    (benchmarks/guard.py BUDGETS: <= 5% of latency_per_tick)."""
    from repro.core.engine import stack_sources
    from repro.telemetry.metrics import TelemetryConfig
    lat = next((u for n, u, _ in ROWS if n == "latency_per_tick"), None)
    rng = np.random.default_rng(11)
    T = 32
    stacked = stack_sources([{"S1": zipf_batch(rng, 256, tick=t)}
                             for t in range(T)])
    c_off = _chunk_stepper(stacked, TelemetryConfig(impl="ref",
                                                    latency_buckets=0))
    c_on = _chunk_stepper(stacked, TelemetryConfig(impl="ref"))
    delta = _paired_delta(c_off, c_on, T)
    pct = f"{100 * delta / lat:.1f}% of latency_per_tick" if lat else "?"
    row("histogram_update_overhead", delta,
        f"per-arc latency histogram in the jitted chunk tick: "
        f"+{delta:.1f}us ({pct}; target <= 5%)")


def bench_event_latency():
    """End-to-end event latency from the device histograms under a
    backlogged feed (ingest 2x the per-tick batch budget, so queue
    delay grows through the window) — the paper's < 2 s claim mapped
    to source ticks, read at one chunk boundary with zero added
    syncs."""
    from repro.telemetry.metrics import TelemetryConfig
    T = 32
    # window < T: the first window's histogram delta is zero by the
    # mark convention, so quantiles come from the later (backlogged)
    # windows
    eng, state = counting_engine(
        batch_size=256, queue_capacity=1 << 14,
        telemetry=TelemetryConfig(impl="ref", window=T // 4))
    rng = np.random.default_rng(17)

    def src(t, _mx):
        return {"S1": zipf_batch(rng, 512, tick=t)}

    state, _ = eng.run(state, src, T)
    rep = eng.telemetry.last or eng.telemetry.observe(eng, state)
    row("event_latency_p99", rep.event_latency_p99,
        f"p50/p90/p99 = {rep.event_latency_p50:.1f}/"
        f"{rep.event_latency_p90:.1f}/{rep.event_latency_p99:.1f} "
        f"source ticks at updater dequeue (windowed device histogram, "
        f"backlogged 2x feed)")


_CLOSED_LOOP_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater
from repro.core.workflow import Workflow
from repro.core.distributed import DistConfig, DistributedEngine
from repro.telemetry import LoadAutoscaler, TelemetryConfig

VSPEC = {'x': ((), jnp.float32)}

class Counter(AssociativeUpdater):
    name = 'U1'; subscribes = ('S1',); in_value_spec = VSPEC
    out_streams = {}; table_capacity = 1 << 13
    def slate_spec(self): return {'count': ((), jnp.int32)}
    def lift(self, b): return {'count': jnp.ones_like(b.key)}
    def combine(self, a, b): return {'count': a['count'] + b['count']}
    def merge(self, s, d): return {'count': s['count'] + d['count']}

G = 64
def feed(t):
    rng = np.random.default_rng(t)
    keys = rng.integers(0, 1 << 12, G).astype(np.int32)
    hi = (t // 15) % 2 == 0
    return keys, np.arange(G) < (G if hi else G // 10)

def gbv(keys, valid, t, n_sh):
    shp = lambda a: a.reshape(n_sh, -1)
    return EventBatch(sid=jnp.zeros(shp(keys).shape, jnp.int32),
                      ts=jnp.full(shp(keys).shape, t, jnp.int32),
                      key=jnp.asarray(shp(keys)),
                      value={'x': jnp.ones(shp(keys).shape, jnp.float32)},
                      valid=jnp.asarray(shp(valid)))

ctl = LoadAutoscaler(high=0.75, low=0.25, window=3, dwell=2, cooldown=1,
                     min_shards=2, max_shards=4)
mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
eng = DistributedEngine(Workflow([Counter()], external_streams=('S1',)),
                        mesh, DistConfig(
                            batch_size=32, queue_capacity=256,
                            exchange_slack=8.0, autoscale=ctl,
                            telemetry=TelemetryConfig(width=256,
                                                      alpha=1.0)))
state = eng.init_state()
trace = []
def src(t, _mx):
    trace.append(len(eng.active_shards))
    return {'S1': gbv(*feed(t), t, eng.n_shards)}
t0 = time.perf_counter()
state, _ = eng.run(state, src, 60)
jax.block_until_ready(state['tick'])
us = (time.perf_counter() - t0) * 1e6 / 60
segs, cur, n = [], trace[0], 0
for s in trace + [None]:
    if s == cur:
        n += 1
    else:
        segs.append(f"{cur}x{n}"); cur, n = s, 1
print(f"CLOSEDLOOP,{us:.2f},{'|'.join(segs)}")
"""


def bench_closed_loop():
    import subprocess
    root = os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run(
        [sys.executable, "-c", _CLOSED_LOOP_CODE], capture_output=True,
        text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
    if r.returncode != 0:      # pragma: no cover - surfacing CI breakage
        raise RuntimeError(f"closed-loop bench failed:\n{r.stderr}")
    for line in r.stdout.splitlines():
        if line.startswith("CLOSEDLOOP,"):
            _, us, segs = line.split(",")
            row("closed_loop_scale", float(us),
                f"square-wave load, LoadAutoscaler 2->4->2: shard "
                f"trace {segs} (us/tick incl. reconfigures)")


# ----------------------------------------------------------------------
# WAL replay (beyond-paper recovery)
# ----------------------------------------------------------------------

def bench_wal():
    from repro.core.event import EventBatch
    from repro.slates.wal import WriteAheadLog
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(os.path.join(d, "w.log"))
        rng = np.random.default_rng(4)
        b = uniform_batch(rng, 4096)
        for t in range(32):
            wal.append(t, {"S1": b})
        wal.close()
        wal2 = WriteAheadLog(os.path.join(d, "w.log"))

        def replay():
            n = 0
            for _, src in wal2.replay():
                n += int(np.asarray(src["S1"].valid).sum())
            return n

        us = _time(replay, n=3, warmup=1)
        n = replay()
        row("wal_replay", us, f"{n/(us/1e6):.2e} events/s replayed")
        wal2.close()


def bench_durability():
    """Durable-runtime costs (DESIGN.md section 10): the write-ahead
    append on the ingest path (target: <= 15% of latency_per_tick) and
    end-to-end crash recovery (store restore + WAL replay)."""
    from repro.core.durability import DurabilityConfig
    from repro.core.engine import Engine, EngineConfig
    from repro.core.workflow import Workflow
    from repro.slates.flush import FlushConfig, FlushPolicy
    from repro.slates.wal import WriteAheadLog
    from benchmarks.workloads import (CounterUpdater, SourceMapper,
                                      zipf_batch)

    rng = np.random.default_rng(8)
    lat = next((u for n, u, _ in ROWS if n == "latency_per_tick"), None)

    # WAL append of one 256-event tick (what run() adds per tick)
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(os.path.join(d, "w.log"))
        batches = [zipf_batch(rng, 256, tick=t) for t in range(8)]
        box = {"t": 0}

        def append():
            wal.append(box["t"], {"S1": batches[box["t"] % 8]})
            box["t"] += 1

        us = _time(append, n=50)
        pct = f", {100 * us / lat:.1f}% of latency_per_tick" if lat else ""
        row("wal_append_per_tick", us,
            f"write-ahead ingest logging (256-event batch{pct}; "
            f"target <= 15%)")
        wal.close()

    # crash recovery: 32 durable ticks @256 events, flush every 8,
    # crash, then restore + replay on a fresh engine
    def build(d):
        wf = Workflow([SourceMapper(), CounterUpdater()],
                      external_streams=("S1",))
        cfg = EngineConfig(
            batch_size=256, queue_capacity=2048, chunk_size=8,
            durability=DurabilityConfig(
                dir=d, flush=FlushConfig(policy=FlushPolicy.EVERY_K,
                                         every_k=8)))
        return Engine(wf, cfg)

    with tempfile.TemporaryDirectory() as d:
        eng = build(d)

        def src(t, ingest=None):
            r = np.random.default_rng(t)
            return {"S1": zipf_batch(r, 256, tick=t)}

        state, _ = eng.run(eng.init_state(), src, 32)
        n_slates = int(np.asarray(jax.device_get(
            state["tables"]["U1"].occupancy())))
        del state                      # crash
        eng.close()

        eng2 = build(d)
        t0 = time.perf_counter()
        s2 = eng2.recover()
        jax.block_until_ready(s2["tick"])
        us = (time.perf_counter() - t0) * 1e6
        tick2 = int(np.asarray(jax.device_get(s2["tick"])))
        eng2.close()
        row("recovery_time", us,
            f"restore {n_slates} slates + replay to tick {tick2} "
            f"({us/1e3:.1f} ms; includes replay jit compile)")


# ----------------------------------------------------------------------
# serving: tokens/s on the reduced LM (slate-managed decode)
# ----------------------------------------------------------------------

def bench_serving():
    from repro.configs import reduced_config
    from repro.launch.serve import Request, ServeConfig, ServingEngine
    cfg = reduced_config("qwen2-0.5b")
    eng = ServingEngine(cfg, ServeConfig(n_slots=8, cache_len=128,
                                         prompt_bucket=32))
    rng = np.random.default_rng(5)
    for i in range(16):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 12).astype(np.int32), max_new=16))
    eng.run(4)  # warmup / fill slots
    t0 = time.perf_counter()
    n0 = eng.tick
    eng.run(24)
    dt = time.perf_counter() - t0
    tok_s = 8 * 24 / dt  # slots x ticks
    row("serving_decode_tick", dt / 24 * 1e6,
        f"{tok_s:.0f} tok/s at 8 slots (reduced config, CPU)")


# ----------------------------------------------------------------------
# streaming ML (DESIGN.md section 16): model inference inside the tick,
# semantic top-k on the fused max path, LM serving as a MapUpdate app
# ----------------------------------------------------------------------

_ML_CFG = None


def _ml_cfg():
    global _ML_CFG
    if _ML_CFG is None:
        from repro.configs import get_config
        _ML_CFG = get_config("qwen2-0.5b").replace(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
            vocab_size=512, head_dim=32)
    return _ML_CFG


def _run_ml_mapper(key_dtype: str = "int32"):
    """The streaming-ML tick (embed, score, fused max slate scatter) at
    bench scale; shared by the default row and the x64 subprocess.
    Returns ``(B, us_per_tick)``."""
    from repro import App, EventBatch, RuntimeConfig
    from repro.api import ops
    cfg = _ml_cfg()
    SEQ, B = 8, 64
    kd = np.dtype(key_dtype)
    app = App("bench_ml")
    app.source("events", {"tokens": ((SEQ,), jnp.int32),
                          "item": ((), jnp.int32)})
    app.add(ops.model_mapper(cfg, field="tokens", out="scored", bucket=8,
                             keep=("item",), name="embed"),
            subscribes=("events",))
    app.stream("scored").update(ops.semantic_topk(
        k=4, n_slots=32, table_capacity=256))
    h = app.start(RuntimeConfig(batch_size=B, key_dtype=key_dtype))
    rng = np.random.default_rng(12)
    batches = []
    for t in range(8):
        toks = rng.integers(1, cfg.vocab_size, (B, SEQ)).astype(np.int32)
        item = rng.integers(1, 1 << 10, B).astype(np.int32)
        topic = rng.integers(0, 64, B).astype(kd)
        batches.append({"events": EventBatch.of(
            key=topic, value={"tokens": toks, "item": item},
            ts=np.full(B, t, np.int32))})
    box = {"s": h.state, "i": 0}

    def step():
        b = batches[box["i"] % len(batches)]
        box["s"], _ = app.engine.step(box["s"], b)
        box["i"] += 1
        jax.block_until_ready(box["s"]["tick"])

    us = _time(step, n=15)
    app.close()
    return B, us


def bench_ml_mapper_throughput():
    """Events/s through a FLOP-heavy ModelMapper stage + semantic top-k
    updater — the full streaming-ML tick (embed, score, fused max slate
    scatter), guarded in CI."""
    B, us = _run_ml_mapper()
    row("ml_mapper_throughput", us,
        f"{B/(us/1e6):.0f} events/s: 2-layer model inference "
        f"(bucket=8 microbatches) + fused max slate tick")


_X64_CODE = r"""
import os
os.environ["JAX_ENABLE_X64"] = "1"
from benchmarks import run as bench
B, us = bench._run_ml_mapper(key_dtype="int64")
print(f"X64,{us:.2f},{B}")
"""


def bench_ml_mapper_throughput_x64():
    """The same streaming-ML tick under ``jax_enable_x64`` with int64
    keys, in a subprocess (the flag is process-global) — the measured
    cost of the wide-key mode on an f32 model path, answering the PR-9
    open item: compare against ``ml_mapper_throughput`` before
    defaulting any workload to 64-bit keys."""
    import subprocess
    root = os.path.join(os.path.dirname(__file__), "..")
    r = subprocess.run(
        [sys.executable, "-c", _X64_CODE], capture_output=True,
        text=True, timeout=560,
        env={**os.environ,
             "PYTHONPATH": os.pathsep.join(
                 [root, os.path.join(root, "src")])})
    if r.returncode != 0:      # pragma: no cover - surfacing CI breakage
        raise RuntimeError(f"x64 ml-mapper bench failed:\n{r.stderr}")
    base = next((u for n, u, _ in ROWS
                 if n == "ml_mapper_throughput"), None)
    for line in r.stdout.splitlines():
        if line.startswith("X64,"):
            _, us, B = line.split(",")
            us, B = float(us), int(B)
            vs = (f", {us / base:.2f}x the int32/f32 row" if base else "")
            row("ml_mapper_throughput_x64", us,
                f"{B/(us/1e6):.0f} events/s with jax_enable_x64 + "
                f"int64 keys (same model, subprocess){vs}")


def bench_semantic_topk():
    """The updater alone at counting-bench scale: pre-scored events
    straight into the packed max-sketch slate (no model in the loop)."""
    from repro import App, EventBatch, RuntimeConfig
    from repro.api import ops
    B, D = 2048, 16
    app = App("bench_topk")
    app.source("scored", {"emb": ((D,), jnp.float32),
                          "item": ((), jnp.int32)})
    app.stream("scored").update(ops.semantic_topk(
        k=8, n_slots=64, table_capacity=1 << 12))
    h = app.start(RuntimeConfig(batch_size=B, queue_capacity=4 * B))
    rng = np.random.default_rng(13)
    batches = []
    for t in range(8):
        z = zipf_batch(rng, B, tick=t)
        batches.append({"scored": EventBatch.of(
            key=z.key,
            value={"emb": rng.standard_normal((B, D)).astype(np.float32),
                   "item": rng.integers(1, 1 << 10, B).astype(np.int32)},
            ts=np.full(B, t, np.int32))})
    box = {"s": h.state, "i": 0}

    def step():
        b = batches[box["i"] % len(batches)]
        box["s"], _ = app.engine.step(box["s"], b)
        box["i"] += 1
        jax.block_until_ready(box["s"]["tick"])

    us = _time(step, n=20)
    row("semantic_topk_per_tick", us,
        f"{B/(us/1e6):.0f} slate updates/s on the fused elementwise-max "
        f"path (Zipf keys, 64-slot sketch)")
    app.close()


def bench_serve_lm_app():
    """Tokens/s of the LM-serving-as-MapUpdate-app path (DESIGN 16.4):
    admission source -> prefill + scan-decode mapper -> request slate,
    compared against the direct ServingEngine loop (serving_decode_tick
    above runs the reduced config; this runs the bench-tiny one)."""
    from repro import RuntimeConfig
    from repro.launch.serve import Request
    from repro.ml.serve_app import build_serve_app, request_source
    cfg = _ml_cfg()
    PROMPT, MAX_NEW = 16, 8
    rng = np.random.default_rng(14)

    def mk_reqs(n, base):
        return [Request(rid=base + i,
                        prompt=rng.integers(1, cfg.vocab_size,
                                            8).astype(np.int32),
                        max_new=MAX_NEW) for i in range(n)]

    app = build_serve_app(cfg, prompt_len=PROMPT, max_new=MAX_NEW,
                          cache_len=64, bucket=4, table_capacity=256)
    rt = RuntimeConfig(batch_size=8)
    # warm: compile the prefill+decode microbatch at the serving shapes
    app.run(request_source(mk_reqs(8, 1), prompt_len=PROMPT, capacity=8,
                           per_tick=4), n_ticks=2, runtime=rt, drain=True)
    n_req, n_ticks = 24, 6
    src = request_source(mk_reqs(n_req, 100), prompt_len=PROMPT,
                         capacity=8, per_tick=4)
    t0 = time.perf_counter()
    app.run(src, n_ticks=n_ticks, drain=True)
    dt = time.perf_counter() - t0
    row("serve_lm_engine_tok_s", dt / n_ticks * 1e6,
        f"{n_req * MAX_NEW / dt:.0f} tok/s through the MapUpdate serving "
        f"app ({n_req} requests, greedy decode, durable-ready path)")
    app.close()


# ----------------------------------------------------------------------
# CI regression-guard anchor (benchmarks/guard.py)
# ----------------------------------------------------------------------

def bench_guard_calibration():
    """A fixed, workload-independent anchor — a jitted argsort over a
    constant 64k array — recorded into every BENCH_<n>.json.  The CI
    ratio guard divides each guarded metric by this anchor on both
    sides of the comparison, cancelling machine-speed differences so
    the pinned baseline stays meaningful across runners."""
    x = jnp.asarray(np.random.default_rng(42).standard_normal(1 << 16),
                    jnp.float32)
    f = jax.jit(lambda a: jnp.argsort(a))
    f(x).block_until_ready()
    us = _time_min(lambda: f(x).block_until_ready(), n=30)
    row("guard_calibration", us,
        "fixed jitted argsort(65536): machine-speed anchor for the "
        "CI bench ratio guard")


# ----------------------------------------------------------------------
# kernels (ref-path timings; Pallas targets TPU, validated in tests)
# ----------------------------------------------------------------------

def bench_kernels():
    from repro.kernels.attention.ref import mha
    from repro.kernels.ssd.ref import ssd
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.float32)
    mha(q, k, v).block_until_ready()
    us = _time(lambda: mha(q, k, v).block_until_ready(), n=10)
    flops = 2 * 2 * 1024 * 1024 * 8 * 64
    row("flash_ref_1k_8h", us, f"{flops/(us*1e-6)/1e9:.1f} GFLOP/s ref")

    qs = jax.random.normal(ks[0], (2, 512, 4, 32), jnp.float32)
    kss = jax.random.normal(ks[1], (2, 512, 4, 32), jnp.float32) * 0.3
    vs = jax.random.normal(ks[2], (2, 512, 4, 64), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (2, 512, 4)))
    ssd(qs, kss, vs, la)[0].block_until_ready()
    us = _time(lambda: ssd(qs, kss, vs, la)[0].block_until_ready(), n=10)
    row("ssd_ref_512x4h", us, "chunked linear recurrence (ref)")


def main() -> None:
    print("name,us_per_call,derived")
    bench_event_throughput()
    bench_sequential_throughput()
    bench_chunked_vs_pertick()
    bench_fused_slate_update()
    bench_fused_mapper_chain()
    bench_latency()
    bench_hotspot_key_splitting()
    bench_slate_read()
    bench_slate_store()
    bench_failover()
    bench_elasticity()
    bench_telemetry_overhead()
    bench_histogram_overhead()
    bench_event_latency()
    bench_closed_loop()
    bench_wal()
    bench_durability()
    bench_latency_breakdown()
    bench_serving()
    bench_ml_mapper_throughput()
    bench_ml_mapper_throughput_x64()
    bench_semantic_topk()
    bench_serve_lm_app()
    bench_guard_calibration()
    bench_kernels()
    root = os.path.join(os.path.dirname(__file__), "..")
    out = os.path.join(root, "experiments", "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump([{"name": n, "us_per_call": u, "derived": d}
                   for n, u, d in ROWS], f, indent=2)
    # machine-readable perf trajectory: BENCH_<n>.json, name -> us/call
    bench_id = os.environ.get("BENCH_ID", "1")
    with open(os.path.join(root, f"BENCH_{bench_id}.json"), "w") as f:
        json.dump({n: round(u, 2) for n, u, _ in ROWS}, f, indent=2,
                  sort_keys=True)


if __name__ == "__main__":
    main()
