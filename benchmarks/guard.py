"""CI bench-regression guard (tier-1).

Re-measures a small set of fast, stable benchmarks and compares them
against the pinned ``BENCH_<n>.json`` baseline at the repo root,
failing (exit 1) when any guarded metric regresses by more than
``BENCH_GUARD_TOL`` (default 15%).

Raw microseconds are meaningless across runners, so both sides are
normalized by the ``guard_calibration`` anchor (a fixed jitted argsort
recorded into every baseline by ``benchmarks/run.py``):

    ratio = (cur[m] / cur[anchor]) / (base[m] / base[anchor])

A ratio above ``1 + tol`` is a regression.  Measurement is best-of-N
attempts (default 3): CI runners are noisy, and a guard that cries
wolf gets deleted — only a regression that survives every attempt
fails the build.  Baselines predating the anchor are skipped (exit 0)
rather than compared against garbage.

Guard-context pinning (``--pin``): dispatch-bound metrics shift by
tens of percent between measurement *contexts* (full-suite process
state, scheduler company on small machines) even when machine speed —
which the argsort anchor tracks — is identical.  So the baseline the
guard compares against must be measured by the guard's own code path:
``guard.py --pin`` re-measures the guarded metrics + anchor exactly as
a guard run would and merges them into the pinned ``BENCH_<n>.json``
under ``guard:``-prefixed keys (the full-suite trajectory numbers are
left untouched).  ``main()`` prefers those keys and falls back to the
plain names for old baselines.  CI pins right after emitting a fresh
baseline (bench-smoke job), so checks always compare guard-context to
guard-context.
"""
from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

GUARDED = ("latency_per_tick", "tick_dispatch_chunked32",
           "slate_read_qps", "ml_mapper_throughput",
           "wal_append_per_tick", "throughput_associative_events")
# budget guards: metric must stay within frac * reference *within the
# same measurement attempt* — no baseline or anchor normalization
# needed, so tiny paired-delta metrics (too noisy for the 15% ratio
# guard) still get a hard CI ceiling.
BUDGETS = {"histogram_update_overhead": ("latency_per_tick", 0.05)}
ANCHOR = "guard_calibration"
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def load_baseline():
    """The pinned baseline: BENCH_ID if set, else the highest-numbered
    BENCH_<n>.json in the repo root."""
    bid = os.environ.get("BENCH_ID")
    if bid:
        path = os.path.join(ROOT, f"BENCH_{bid}.json")
        return (json.load(open(path)), path) if os.path.exists(path) \
            else (None, path)
    best, best_n = None, -1
    for path in glob.glob(os.path.join(ROOT, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(path))
        if m and int(m.group(1)) > best_n:
            best, best_n = path, int(m.group(1))
    return (json.load(open(best)), best) if best else (None, None)


def base_val(base: dict, name: str):
    """Guard-context entry if the baseline was pinned, else the
    full-suite number (old baselines)."""
    return base.get(f"guard:{name}", base.get(name))


def measure():
    """One attempt: the guarded benches + the anchor, in-process."""
    from benchmarks import run as bench
    bench.ROWS.clear()
    bench.bench_latency()
    bench.bench_chunked_vs_pertick()
    bench.bench_slate_read()
    bench.bench_ml_mapper_throughput()
    bench.bench_event_throughput()
    bench.bench_durability()
    bench.bench_histogram_overhead()
    bench.bench_guard_calibration()
    out = {n: u for n, u, _ in bench.ROWS}
    bench.ROWS.clear()
    return out


def pin(attempts: int = 3) -> int:
    """Merge guard-context measurements (best of ``attempts``) into the
    pinned baseline under ``guard:``-prefixed keys.

    Pinning is *ratio-consistent*: the stored value for each metric is
    its best observed metric/anchor ratio **within a single attempt**,
    rescaled by the pinned anchor.  Taking the min of each metric and
    the min of the anchor independently across attempts would pair a
    fast metric from one attempt with a fast anchor from another —
    biasing every baseline ratio low, so the check (which always
    compares within one attempt) flakes whenever the anchor and the
    dispatch-bound metrics jitter out of phase."""
    base, path = load_baseline()
    if base is None:
        print(f"bench guard: no baseline to pin ({path or 'BENCH_*.json'})")
        return 1
    runs = [measure() for _ in range(attempts)]
    anchor = sorted(r[ANCHOR] for r in runs)[len(runs) // 2]   # median
    base[f"guard:{ANCHOR}"] = round(anchor, 2)
    print(f"  pinned guard:{ANCHOR} = {anchor:.2f}us (median)")
    for name in GUARDED + tuple(BUDGETS):
        ratio = min(r[name] / r[ANCHOR] for r in runs)
        base[f"guard:{name}"] = round(ratio * anchor, 2)
        print(f"  pinned guard:{name} = {ratio * anchor:.2f}us "
              f"(best in-attempt ratio x median anchor)")
    with open(path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
    print(f"bench guard: pinned guard-context baseline into {path}")
    return 0


def main() -> int:
    tol = float(os.environ.get("BENCH_GUARD_TOL", "0.15"))
    attempts = int(os.environ.get("BENCH_GUARD_ATTEMPTS", "3"))
    base, path = load_baseline()
    if base is None:
        print(f"bench guard: no baseline ({path or 'BENCH_*.json'}); "
              f"skipping")
        return 0
    b_anchor = base_val(base, ANCHOR)
    if not b_anchor or b_anchor <= 0:
        print(f"bench guard: baseline {path} predates the "
              f"{ANCHOR!r} anchor; skipping")
        return 0
    missing = [m for m in GUARDED if base_val(base, m) is None]
    if missing:
        print(f"bench guard: baseline {path} lacks {missing}; skipping")
        return 0
    worst = {}
    for attempt in range(1, attempts + 1):
        cur = measure()
        bad = []
        for m in GUARDED:
            ratio = (cur[m] / cur[ANCHOR]) / (base_val(base, m) / b_anchor)
            worst[m] = min(worst.get(m, float("inf")), ratio)
            mark = "FAIL" if ratio > 1 + tol else "ok"
            print(f"  [{attempt}/{attempts}] {m}: {cur[m]:.1f}us, "
                  f"normalized ratio {ratio:.3f} vs {path} ({mark})")
            if ratio > 1 + tol:
                bad.append(m)
        for m, (ref, frac) in BUDGETS.items():
            # hard ceiling within the same attempt: cur vs frac * ref,
            # both measured moments apart on the same machine — no
            # baseline, no anchor, no cross-runner normalization
            ratio = cur[m] / max(1e-9, frac * cur[ref])
            worst[m] = min(worst.get(m, float("inf")), ratio)
            mark = "FAIL" if ratio > 1.0 else "ok"
            print(f"  [{attempt}/{attempts}] {m}: {cur[m]:.2f}us, "
                  f"{100 * cur[m] / max(1e-9, cur[ref]):.1f}% of {ref} "
                  f"(budget {frac:.0%}) ({mark})")
            if ratio > 1.0:
                bad.append(m)
        if not bad:
            print(f"bench guard: pass (tol {tol:.0%})")
            return 0
    # no attempt was clean across the board — but "regression" means a
    # metric that failed in EVERY attempt (per-metric best ratio), not
    # "no single attempt where all N noisy metrics lined up at once"
    fails = [m for m, r in worst.items()
             if r > (1.0 if m in BUDGETS else 1 + tol)]
    if not fails:
        print(f"bench guard: pass (tol {tol:.0%}; every metric cleared "
              f"in at least one of {attempts} attempts)")
        return 0
    print(f"bench guard: FAIL — {fails} regressed in every attempt "
          f"(best ratios { {m: round(worst[m], 3) for m in fails} }; "
          f"ratio-guard tol {tol:.0%}, budget guards hard)")
    return 1


if __name__ == "__main__":
    sys.exit(pin() if "--pin" in sys.argv[1:] else main())
