"""CI bench-regression guard (tier-1).

Re-measures a small set of fast, stable benchmarks and compares them
against the pinned ``BENCH_<n>.json`` baseline at the repo root,
failing (exit 1) when any guarded metric regresses by more than
``BENCH_GUARD_TOL`` (default 15%).

Raw microseconds are meaningless across runners, so both sides are
normalized by the ``guard_calibration`` anchor (a fixed jitted argsort
recorded into every baseline by ``benchmarks/run.py``):

    ratio = (cur[m] / cur[anchor]) / (base[m] / base[anchor])

A ratio above ``1 + tol`` is a regression.  Measurement is best-of-N
attempts (default 3): CI runners are noisy, and a guard that cries
wolf gets deleted — only a regression that survives every attempt
fails the build.  Baselines predating the anchor are skipped (exit 0)
rather than compared against garbage.

Guard-context pinning (``--pin``): dispatch-bound metrics shift by
tens of percent between measurement *contexts* (full-suite process
state, scheduler company on small machines) even when machine speed —
which the argsort anchor tracks — is identical.  So the baseline the
guard compares against must be measured by the guard's own code path:
``guard.py --pin`` re-measures the guarded metrics + anchor exactly as
a guard run would and merges them into the pinned ``BENCH_<n>.json``
under ``guard:``-prefixed keys (the full-suite trajectory numbers are
left untouched).  ``main()`` prefers those keys and falls back to the
plain names for old baselines.  CI pins right after emitting a fresh
baseline (bench-smoke job), so checks always compare guard-context to
guard-context.
"""
from __future__ import annotations

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

GUARDED = ("latency_per_tick", "tick_dispatch_chunked32",
           "slate_read_qps", "ml_mapper_throughput",
           "wal_append_per_tick", "throughput_associative_events")
ANCHOR = "guard_calibration"
ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def load_baseline():
    """The pinned baseline: BENCH_ID if set, else the highest-numbered
    BENCH_<n>.json in the repo root."""
    bid = os.environ.get("BENCH_ID")
    if bid:
        path = os.path.join(ROOT, f"BENCH_{bid}.json")
        return (json.load(open(path)), path) if os.path.exists(path) \
            else (None, path)
    best, best_n = None, -1
    for path in glob.glob(os.path.join(ROOT, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(path))
        if m and int(m.group(1)) > best_n:
            best, best_n = path, int(m.group(1))
    return (json.load(open(best)), best) if best else (None, None)


def base_val(base: dict, name: str):
    """Guard-context entry if the baseline was pinned, else the
    full-suite number (old baselines)."""
    return base.get(f"guard:{name}", base.get(name))


def measure():
    """One attempt: the guarded benches + the anchor, in-process."""
    from benchmarks import run as bench
    bench.ROWS.clear()
    bench.bench_latency()
    bench.bench_chunked_vs_pertick()
    bench.bench_slate_read()
    bench.bench_ml_mapper_throughput()
    bench.bench_event_throughput()
    bench.bench_durability()
    bench.bench_guard_calibration()
    out = {n: u for n, u, _ in bench.ROWS}
    bench.ROWS.clear()
    return out


def pin(attempts: int = 3) -> int:
    """Merge guard-context measurements (best of ``attempts``) into the
    pinned baseline under ``guard:``-prefixed keys."""
    base, path = load_baseline()
    if base is None:
        print(f"bench guard: no baseline to pin ({path or 'BENCH_*.json'})")
        return 1
    best = {}
    for _ in range(attempts):
        cur = measure()
        for name, us in cur.items():
            best[name] = min(best.get(name, float("inf")), us)
    for name in GUARDED + (ANCHOR,):
        base[f"guard:{name}"] = round(best[name], 2)
        print(f"  pinned guard:{name} = {best[name]:.2f}us")
    with open(path, "w") as f:
        json.dump(base, f, indent=2, sort_keys=True)
    print(f"bench guard: pinned guard-context baseline into {path}")
    return 0


def main() -> int:
    tol = float(os.environ.get("BENCH_GUARD_TOL", "0.15"))
    attempts = int(os.environ.get("BENCH_GUARD_ATTEMPTS", "3"))
    base, path = load_baseline()
    if base is None:
        print(f"bench guard: no baseline ({path or 'BENCH_*.json'}); "
              f"skipping")
        return 0
    b_anchor = base_val(base, ANCHOR)
    if not b_anchor or b_anchor <= 0:
        print(f"bench guard: baseline {path} predates the "
              f"{ANCHOR!r} anchor; skipping")
        return 0
    missing = [m for m in GUARDED if base_val(base, m) is None]
    if missing:
        print(f"bench guard: baseline {path} lacks {missing}; skipping")
        return 0
    worst = {}
    for attempt in range(1, attempts + 1):
        cur = measure()
        bad = []
        for m in GUARDED:
            ratio = (cur[m] / cur[ANCHOR]) / (base_val(base, m) / b_anchor)
            worst[m] = min(worst.get(m, float("inf")), ratio)
            mark = "FAIL" if ratio > 1 + tol else "ok"
            print(f"  [{attempt}/{attempts}] {m}: {cur[m]:.1f}us, "
                  f"normalized ratio {ratio:.3f} vs {path} ({mark})")
            if ratio > 1 + tol:
                bad.append(m)
        if not bad:
            print(f"bench guard: pass (tol {tol:.0%})")
            return 0
    fails = [m for m, r in worst.items() if r > 1 + tol]
    print(f"bench guard: FAIL — {fails} regressed > {tol:.0%} in every "
          f"attempt (best normalized ratios "
          f"{ {m: round(worst[m], 3) for m in fails} })")
    return 1


if __name__ == "__main__":
    sys.exit(pin() if "--pin" in sys.argv[1:] else main())
