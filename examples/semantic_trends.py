"""Semantic trends: event stream -> ModelMapper embeddings ->
per-topic semantic top-k (DESIGN.md section 16) — the streaming-ML
shape of Twitter's real-time related-query pipeline: heavy per-event
featurization feeding an incrementally-updated per-key ranking.

Events carry a token window and an item id, keyed by topic.  A
FLOP-heavy :class:`ModelMapper` stage embeds each event's tokens with
a small transformer inside the jitted tick; ``semantic_topk`` keeps,
per topic, the best-scoring items on the fused elementwise-max slate
path.  The demo self-asserts against a host-side replay of the same
scores.

Run:  PYTHONPATH=src python examples/semantic_trends.py
"""
import numpy as np

from repro import App, EventBatch, RuntimeConfig
from repro.api import ops
from repro.configs import get_config
from repro.ml.rankers import ITEM_BITS

import jax.numpy as jnp

N_TOPICS = 4
SEQ = 8
K = 4

cfg = get_config("qwen2-0.5b").replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=32)

# --- app ---------------------------------------------------------------
app = App("semantic_trends")
app.source("events", {"tokens": ((SEQ,), jnp.int32),
                      "item": ((), jnp.int32)})
embed = ops.model_mapper(cfg, field="tokens", out="scored", bucket=8,
                         keep=("item",), name="embed")
app.add(embed, subscribes=("events",))
ranker = ops.semantic_topk(k=K, n_slots=32, table_capacity=64)
app.stream("scored").update(ranker)
# --- end app -----------------------------------------------------------


def main():
    rng = np.random.default_rng(0)
    fed = []      # (topic, item, tokens) ground truth of what went in

    def source_fn(tick, max_events):
        B = 32
        toks = rng.integers(1, cfg.vocab_size, (B, SEQ)).astype(np.int32)
        item = rng.integers(1, 1 << ITEM_BITS, B).astype(np.int32)
        topic = rng.integers(0, N_TOPICS, B).astype(np.int32)
        valid = np.arange(B) < (max_events or B)
        for i in np.nonzero(valid)[0]:
            fed.append((int(topic[i]), int(item[i]), toks[i].copy()))
        return {"events": EventBatch.of(
            key=topic, value={"tokens": toks, "item": item},
            ts=np.full(B, tick, np.int32), valid=valid)}

    app.run(source_fn, n_ticks=8,
            runtime=RuntimeConfig(batch_size=32), drain=True)

    # host-side replay: embed the same token windows through the same
    # mapper (no engine) and rank per topic with the same packing
    from repro.ml.rankers import pack_word
    import jax
    all_toks = jnp.asarray(np.stack([t for _, _, t in fed]))
    embs = jax.jit(embed._infer)(all_toks)              # one batched call
    scores = jax.nn.sigmoid(jnp.mean(embs, axis=-1))
    items = jnp.asarray([i for _, i, _ in fed], jnp.int32)
    words = np.asarray(pack_word(scores, items))
    by_topic = {t: {} for t in range(N_TOPICS)}
    for (topic, item, _), w in zip(fed, words):
        col = item % ranker.n_slots
        by_topic[topic][col] = max(by_topic[topic].get(col, 0.0),
                                   float(w))

    print(f"fed {len(fed)} events over {N_TOPICS} topics")
    for t in range(N_TOPICS):
        slate = app.read_slate("semantic_topk", t)
        assert slate is not None, f"topic {t} has no slate"
        got = ranker.top(slate)
        want_cells = np.zeros(ranker.n_slots, np.float32)
        for col, w in by_topic[t].items():
            want_cells[col] = w
        assert np.array_equal(np.asarray(slate["cells"]), want_cells), \
            f"topic {t}: slate cells diverge from host replay"
        print(f"  topic {t}: top items {[(i, round(s, 4)) for i, s in got]}")
    print("OK: streamed slates match the host-side replay bitwise")
    app.close()


if __name__ == "__main__":
    main()
