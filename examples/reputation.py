"""Paper Example 3: maintain a reputation score per Twitter user —
declarative builder edition.

"if a user A retweets or replies to a user B, then the score of B may
change, depending on the score of A" — order matters (B's bump depends
on A's *current* score), so the update is a sequential step function:
strict per-key timestamp order via the padded-run scan.  Both operators
are plain decorated functions; subscriptions and value specs are
inferred by tracing.

Run:  PYTHONPATH=src python examples/reputation.py
"""
import jax.numpy as jnp
import numpy as np

from repro import App, EventBatch, RuntimeConfig

N_USERS = 200

app = App("reputation")
tweets = app.source("tweets", {"target": ((), jnp.int32),
                               "actor_score": ((), jnp.float32)})


@app.mapper(tweets, out="S2", name="M1")
def interaction(batch):
    """M1: tweet -> <target_user, actor_score> scoring event."""
    return EventBatch(sid=batch.sid, ts=batch.ts + 1,
                      key=batch.value["target"],
                      value={"actor_score": batch.value["actor_score"]},
                      valid=batch.valid)


@app.seq_updater("S2", name="U1", table_capacity=1024, max_run=32,
                 slate={"score": ((), jnp.float32),
                        "interactions": ((), jnp.int32)})
def reputation(slate, ev):
    """U1: score' = 0.95*score + 0.05*actor_score + 0.01 (sequential:
    the bump size depends on the score's current value)."""
    new_score = (0.95 * slate["score"]
                 + 0.05 * ev["value"]["actor_score"] + 0.01)
    return ({"score": new_score,
             "interactions": slate["interactions"] + 1}, {})


def main():
    rng = np.random.default_rng(0)
    N = 512

    def source_fn(tick, max_events):
        # celebrity users 0..4 get mentioned by high-score actors
        celebrity = rng.random(N) < 0.3
        target = np.where(celebrity, rng.integers(0, 5, N),
                          rng.integers(5, N_USERS, N)).astype(np.int32)
        actor_score = np.where(celebrity, rng.uniform(0.8, 1.0, N),
                               rng.uniform(0.0, 0.3, N)).astype(np.float32)
        return {"tweets": EventBatch.of(
            key=rng.integers(0, 1 << 30, N).astype(np.int32),
            value={"target": target, "actor_score": actor_score},
            ts=np.full(N, tick, np.int32))}

    app.run(source_fn, n_ticks=30,
            runtime=RuntimeConfig(batch_size=1024, queue_capacity=4096),
            drain=True)

    scores = []
    for u in range(N_USERS):
        s = app.read_slate("U1", u)
        if s is not None:
            scores.append((float(s["score"]), int(s["interactions"]), u))
    scores.sort(reverse=True)
    print("top-10 reputation:")
    for sc, n, u in scores[:10]:
        print(f"  user {u:4d}: score={sc:.3f}  ({n} interactions)")
    top5 = {u for _, _, u in scores[:5]}
    assert top5 == {0, 1, 2, 3, 4}, top5
    print("\ncelebrities 0-4 rank on top — OK")
    print("processed:", app.stats()["processed"])
    app.close()


if __name__ == "__main__":
    main()
