"""Paper Example 3: maintain a reputation score per Twitter user.

"if a user A retweets or replies to a user B, then the score of B may
change, depending on the score of A" — order matters (B's bump depends
on A's *current* score), so this is a SequentialUpdater: strict per-key
timestamp order via the padded-run scan.

The interaction event carries the actor's score snapshot (as the engine's
previous-tick output — scores are read live, section 4.4); the target's
slate folds it in with exponential decay.

Run:  PYTHONPATH=src python examples/reputation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import Mapper, SequentialUpdater
from repro.core.workflow import Workflow

N_USERS = 200


class InteractionMapper(Mapper):
    """M1: tweet -> <target_user, actor_score> scoring event."""
    name = "M1"
    subscribes = ("tweets",)
    in_value_spec = {"target": ((), jnp.int32),
                     "actor_score": ((), jnp.float32)}
    out_streams = {"S2": {"actor_score": ((), jnp.float32)}}

    def map_batch(self, batch):
        return {"S2": EventBatch(
            sid=batch.sid, ts=batch.ts + 1, key=batch.value["target"],
            value={"actor_score": batch.value["actor_score"]},
            valid=batch.valid)}


class ReputationUpdater(SequentialUpdater):
    """U1: score' = 0.95 * score + 0.05 * actor_score + 0.01 (sequential:
    the bump size depends on the score's current value)."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = {"actor_score": ((), jnp.float32)}
    out_streams = {}
    table_capacity = 1024
    max_run = 32

    def slate_spec(self):
        return {"score": ((), jnp.float32),
                "interactions": ((), jnp.int32)}

    def step(self, slate, ev):
        new_score = (0.95 * slate["score"]
                     + 0.05 * ev["value"]["actor_score"] + 0.01)
        return ({"score": new_score,
                 "interactions": slate["interactions"] + 1}, {})


def main():
    wf = Workflow([InteractionMapper(), ReputationUpdater()],
                  external_streams=("tweets",))
    eng = Engine(wf, EngineConfig(batch_size=1024, queue_capacity=4096))
    state = eng.init_state()

    rng = np.random.default_rng(0)
    # celebrity users 0..4 get mentioned by high-score actors
    true_score = np.zeros(N_USERS, np.float64)
    N = 512
    for tick in range(30):
        celebrity = rng.random(N) < 0.3
        target = np.where(celebrity, rng.integers(0, 5, N),
                          rng.integers(5, N_USERS, N)).astype(np.int32)
        actor_score = np.where(celebrity,
                               rng.uniform(0.8, 1.0, N),
                               rng.uniform(0.0, 0.3, N)
                               ).astype(np.float32)
        batch = EventBatch.of(
            key=rng.integers(0, 1 << 30, N).astype(np.int32),
            value={"target": target, "actor_score": actor_score},
            ts=np.full(N, tick, np.int32))
        state, _ = eng.step(state, {"tweets": batch})

    # drain
    for tick in range(30, 40):
        empty = EventBatch.of(
            key=np.zeros(4, np.int32),
            value={"target": np.zeros(4, np.int32),
                   "actor_score": np.zeros(4, np.float32)},
            ts=np.full(4, tick, np.int32), valid=np.zeros(4, bool))
        state, _ = eng.step(state, {"tweets": empty})

    scores = []
    for u in range(N_USERS):
        s = eng.read_slate(state, "U1", u)
        if s is not None:
            scores.append((float(s["score"]), int(s["interactions"]), u))
    scores.sort(reverse=True)
    print("top-10 reputation:")
    for sc, n, u in scores[:10]:
        print(f"  user {u:4d}: score={sc:.3f}  ({n} interactions)")
    top5 = {u for _, _, u in scores[:5]}
    assert top5 == {0, 1, 2, 3, 4}, top5
    print("\ncelebrities 0-4 rank on top — OK")
    print("processed:", eng.stats(state)["processed"])


if __name__ == "__main__":
    main()
