"""Quickstart: the paper's Example 1/4 — count Foursquare checkins per
retailer, live.

A map function inspects each checkin and emits the retailer id; an
associative update function counts per retailer; slates are queryable
live over HTTP while the stream flows (paper section 4.4).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import AssociativeUpdater, Mapper
from repro.core.workflow import Workflow
from repro.slates.http import SlateServer

RETAILERS = ["Walmart", "Sam's Club", "JCPenney", "Best Buy"]
VSPEC = {"retailer": ((), jnp.int32)}


class RetailerMapper(Mapper):
    """M1: checkin -> <retailer, checkin> event (or nothing)."""
    name = "M1"
    subscribes = ("checkins",)
    in_value_spec = VSPEC
    out_streams = {"S2": VSPEC}

    def map_batch(self, batch):
        rid = batch.value["retailer"]          # -1 = not at a retailer
        return {"S2": EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                                 value={"retailer": rid},
                                 valid=batch.valid & (rid >= 0))}


class Counter(AssociativeUpdater):
    """U1: slate = {count}; merge adds combined per-key deltas."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = VSPEC
    out_streams = {}
    table_capacity = 256

    def slate_spec(self):
        return {"count": ((), jnp.int32)}

    def lift(self, batch):
        return {"count": jnp.ones_like(batch.key)}

    def combine(self, a, b):
        return {"count": a["count"] + b["count"]}

    def merge(self, slate, delta):
        return {"count": slate["count"] + delta["count"]}


def main():
    wf = Workflow([RetailerMapper(), Counter()],
                  external_streams=("checkins",))
    engine = Engine(wf, EngineConfig(batch_size=512, queue_capacity=2048))
    state = engine.init_state()

    box = {"state": state}
    server = SlateServer(
        read_fn=lambda u, k: engine.read_slate(box["state"], u, k),
        stats_fn=lambda: engine.stats(box["state"]))
    print(f"slate reads live at http://127.0.0.1:{server.port}"
          f"/slate/U1/<retailer-id>")

    rng = np.random.default_rng(0)
    true = np.zeros(len(RETAILERS), np.int64)
    for tick in range(50):
        # checkin stream: 20% at a known retailer
        rid = np.where(rng.random(512) < 0.2,
                       rng.integers(0, len(RETAILERS), 512),
                       -1).astype(np.int32)
        for r in rid[rid >= 0]:
            true[r] += 1
        batch = EventBatch.of(key=rng.integers(0, 1 << 30, 512)
                              .astype(np.int32),
                              value={"retailer": rid},
                              ts=np.full(512, tick, np.int32))
        box["state"], _ = engine.step(box["state"], {"checkins": batch})

    # drain the pipeline (2 hops)
    for tick in range(50, 53):
        empty = EventBatch.of(key=np.zeros(512, np.int32),
                              value={"retailer": np.full(512, -1,
                                                         np.int32)},
                              ts=np.full(512, tick, np.int32),
                              valid=np.zeros(512, bool))
        box["state"], _ = engine.step(box["state"], {"checkins": empty})

    print("\nlive counts (HTTP slate fetches):")
    for i, name in enumerate(RETAILERS):
        url = f"http://127.0.0.1:{server.port}/slate/U1/{i}"
        got = json.load(urllib.request.urlopen(url))["count"]
        status = "OK" if got == true[i] else f"MISMATCH (true {true[i]})"
        print(f"  {name:12s} {got:8d}  {status}")
        assert got == true[i]
    print("\nstats:", json.dumps(engine.stats(box["state"]), indent=1))
    server.close()


if __name__ == "__main__":
    main()
