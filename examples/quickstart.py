"""Quickstart: the paper's Example 1/4 — count Foursquare checkins per
retailer, live — in ~15 lines of app code.

The declarative builder (DESIGN.md section 11) replaces the subclass
boilerplate: declare a source, decorate a map function (its name,
subscription, and output value spec are inferred by tracing), attach a
prebuilt counter, and ``app.run()`` owns engine selection and state
threading — slates stay queryable over HTTP while the stream flows
(paper section 4.4), with no ``init_state``/``box`` plumbing::

    app = App("quickstart")
    checkins = app.source("checkins", {"retailer": ((), jnp.int32)})

    @app.mapper(checkins, out="S2", name="M1")
    def at_retailer(batch):           # M1: checkin -> <retailer, checkin>
        rid = batch.value["retailer"]
        return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                          value={"retailer": rid},
                          valid=batch.valid & (rid >= 0))

    at_retailer.update(ops.counter("U1"))          # U1: count per key
    app.run(source_fn, n_ticks=50, runtime=RuntimeConfig(...), drain=True)
    app.read_slate("U1", key)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro import App, EventBatch, RuntimeConfig, ops

RETAILERS = ["Walmart", "Sam's Club", "JCPenney", "Best Buy"]

# --- app (paper Example 1) -------------------------------------------
app = App("quickstart")
checkins = app.source("checkins", {"retailer": ((), jnp.int32)})


@app.mapper(checkins, out="S2", name="M1")
def at_retailer(batch):
    """M1: checkin -> <retailer, checkin> event (or nothing)."""
    rid = batch.value["retailer"]          # -1 = not at a retailer
    return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=rid,
                      value={"retailer": rid},
                      valid=batch.valid & (rid >= 0))


at_retailer.update(ops.counter("U1", table_capacity=256))
# --- end app ---------------------------------------------------------


def main():
    app.start(RuntimeConfig(batch_size=512, queue_capacity=2048))
    server = app.serve()
    print(f"slate reads live at http://127.0.0.1:{server.port}"
          f"/slate/U1/<retailer-id>")

    rng = np.random.default_rng(0)
    true = np.zeros(len(RETAILERS), np.int64)

    def source_fn(tick, max_events):
        # checkin stream: 20% at a known retailer; respect the engine's
        # ingest limit (source throttling, paper section 5) and count
        # ground truth only over what was actually fed
        rid = np.where(rng.random(512) < 0.2,
                       rng.integers(0, len(RETAILERS), 512),
                       -1).astype(np.int32)
        valid = np.arange(512) < (max_events or 512)
        for r in rid[(rid >= 0) & valid]:
            true[r] += 1
        return {"checkins": EventBatch.of(
            key=rng.integers(0, 1 << 30, 512).astype(np.int32),
            value={"retailer": rid},
            ts=np.full(512, tick, np.int32), valid=valid)}

    app.run(source_fn, n_ticks=50, drain=True)

    print("\nlive counts (HTTP slate fetches):")
    for i, name in enumerate(RETAILERS):
        url = f"http://127.0.0.1:{server.port}/slate/U1/{i}"
        got = json.load(urllib.request.urlopen(url))["count"]
        status = "OK" if got == true[i] else f"MISMATCH (true {true[i]})"
        print(f"  {name:12s} {got:8d}  {status}")
        assert got == true[i]
    print("\nstats:", json.dumps(app.stats(), indent=1))
    app.close()


if __name__ == "__main__":
    main()
