"""Serve a small LM through the stream engine itself: the serving loop
as a MapUpdate app (``repro.ml.serve_app``, DESIGN.md section 16.4) —
admission source -> prefill/decode mapper -> per-request slate — with a
token-level parity smoke against the direct ``ServingEngine`` loop the
app path replaces.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 24
"""
import argparse
import time

import numpy as np

from repro import RuntimeConfig, TelemetryConfig
from repro.configs import get_config
from repro.launch.serve import Request, ServeConfig, ServingEngine, \
    lm_params
from repro.ml.serve_app import build_serve_app, request_source

PROMPT_LEN = 32   # == ServeConfig.prompt_bucket: identical prefill shapes
MAX_NEW = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=4096, head_dim=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i + 1,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(5, 30))
                                        ).astype(np.int32),
                    max_new=MAX_NEW)
            for i in range(args.requests)]

    # ---- reference: the direct continuous-batching loop ----
    eng = ServingEngine(cfg, ServeConfig(
        n_slots=8, cache_len=64, prompt_bucket=PROMPT_LEN,
        admit_per_tick=2, queue_capacity=64))
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt.copy(),
                           max_new=r.max_new))
    t0 = time.time()
    while (eng.queue or eng.active.any()) and eng.tick < 2000:
        eng.step()
    dt_direct = time.time() - t0
    direct = {r.rid: list(r.tokens_out) for r in eng.finished}

    # ---- the engine path: same model, same params, as an App ----
    app = build_serve_app(cfg, params=lm_params(eng),
                          prompt_len=PROMPT_LEN, max_new=MAX_NEW,
                          cache_len=64, bucket=4)
    n_ticks = -(-args.requests // 2) + 2
    t0 = time.time()
    app.run(request_source(reqs, prompt_len=PROMPT_LEN,
                           capacity=args.batch, per_tick=2),
            n_ticks=n_ticks,
            runtime=RuntimeConfig(batch_size=args.batch,
                                  telemetry=TelemetryConfig()),
            drain=True)
    dt_app = time.time() - t0

    # ---- parity smoke: token streams must agree request-for-request ----
    matched = 0
    for r in reqs:
        slate = app.read_slate("requests", r.rid)
        assert slate is not None, f"request {r.rid} has no slate"
        got = list(np.asarray(slate["tokens"]))
        assert got == direct[r.rid], \
            f"request {r.rid}: app {got} != direct {direct[r.rid]}"
        matched += 1
    toks = args.requests * MAX_NEW
    print(f"parity OK: {matched}/{args.requests} requests, "
          f"token-for-token vs direct ServingEngine")
    print(f"engine path: {toks} tokens in {dt_app:.1f}s "
          f"({toks / dt_app:.0f} tok/s); direct loop: {dt_direct:.1f}s")
    rep = app.telemetry()   # per-shard vectors; one shard here
    print(f"telemetry: pressure={float(np.max(rep.pressure)):.3f} "
          f"events/tick={float(np.sum(rep.events_per_tick)):.1f}")
    print("stats:", app.stats())
    app.close()


if __name__ == "__main__":
    main()
