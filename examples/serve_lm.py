"""Serve a small LM with batched requests through the Muppet serving
layer: admission queue (bounded, shedding), continuous-batching decode
slots (per-request slates), request latency stats.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 24
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=4096, head_dim=32)
    eng = ServingEngine(cfg, ServeConfig(
        n_slots=args.slots, cache_len=256, prompt_bucket=32,
        admit_per_tick=2, queue_capacity=64))

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                int(rng.integers(5, 30))).astype(np.int32),
            max_new=args.max_new))

    while (eng.queue or eng.active.any()) and eng.tick < 2000:
        eng.step()
    dt = time.time() - t0

    s = eng.stats()
    print(f"finished {s['finished']} requests in {dt:.1f}s "
          f"({s['tokens_generated']} tokens, "
          f"{s['tokens_generated']/dt:.0f} tok/s)")
    print(f"mean latency: {s['mean_latency_ticks']:.1f} ticks; "
          f"shed: {s['shed']}")
    sample = eng.finished[0]
    print(f"request {sample.rid}: prompt[{len(sample.prompt)}] -> "
          f"{sample.tokens_out[:12]}...")
    assert s["finished"] == args.requests


if __name__ == "__main__":
    main()
