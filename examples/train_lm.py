"""End-to-end driver: train a ~100M-parameter qwen2-family model for a
few hundred steps on the streaming synthetic corpus, with async
checkpointing and restart.

Scaled to CPU wall-clock by default (--full-100m uses the real ~100M
config; default is a ~10M config that shows the same loss curve in
minutes).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import tempfile
import time

from repro.configs import get_config
from repro.data.synthetic import Prefetcher, TokenStream
from repro.distributed.optimizer import AdamWConfig
from repro.launch.train import Trainer


def config_100m():
    """~100M params of the qwen2 family."""
    return get_config("qwen2-0.5b").replace(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=2, d_ff=2048,
        vocab_size=32768, head_dim=64)


def config_small():
    """~2M params — same family, CPU-friendly (use --full-100m for the
    real ~100M run on accelerators)."""
    return get_config("qwen2-0.5b").replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=4096, head_dim=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir")
    args = ap.parse_args()

    cfg = config_100m() if args.full_100m else config_small()
    n_params = cfg.param_count()
    print(f"arch family qwen2; params ~{n_params/1e6:.1f}M; "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="muppet_ck_")
    trainer = Trainer(cfg, ckpt_dir=ckpt_dir, ckpt_every=100,
                      opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=20))
    params, opt = trainer.init(0)
    params, opt = trainer.maybe_restore(params, opt)
    stream = Prefetcher(iter(TokenStream(cfg.vocab_size, args.batch,
                                         args.seq, seed=0)), depth=2)
    t0 = time.time()
    params, opt, losses = trainer.run(params, opt, stream, args.steps,
                                      log_every=25)
    dt = time.time() - t0
    tok_s = trainer.step * args.batch * args.seq / dt
    print(f"\n{trainer.step} steps in {dt:.0f}s = {tok_s:.0f} tok/s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(checkpoints in {ckpt_dir})")
    assert losses[-1] < losses[0] - 0.5, "loss should fall"
    trainer.ckpt.save(trainer.step, {"params": params, "opt": opt},
                      blocking=True)
    trainer.close()
    stream.close()


if __name__ == "__main__":
    main()
