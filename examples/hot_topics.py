"""Paper Example 2/5: detect hot topics on a tweet stream.

Workflow (Figure 1c):
  tweets --M1(classify into topic_minute)--> S2
  S2 --U1(count per topic_minute; emit count each minute)--> S3
  S3 --U2(compare to per-minute historical average; emit hot topics)--> S4

M1's "classifier" here is a real (tiny) transformer scoring topics from
the tweet's feature vector — the model stack and the stream engine
compose (DESIGN.md section 3).

Run:  PYTHONPATH=src python examples/hot_topics.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine, EngineConfig
from repro.core.event import EventBatch
from repro.core.operators import (AssociativeUpdater, Mapper,
                                  SequentialUpdater)
from repro.core.workflow import Workflow

N_TOPICS = 16
FEAT = 32
TICKS_PER_MINUTE = 4


class TopicClassifierMapper(Mapper):
    """M1: classify the tweet's feature vector into a topic (a matched
    filter against learned topic embeddings — the map function runs a
    model inside the stream, as Kosmix's classifiers did)."""
    name = "M1"
    subscribes = ("tweets",)
    in_value_spec = {"feat": ((FEAT,), jnp.float32)}
    out_streams = {"S2": {"topic": ((), jnp.int32)}}

    def __init__(self, topic_embeds):
        self.w = jnp.asarray(topic_embeds.T)     # [FEAT, N_TOPICS]

    def map_batch(self, batch):
        topic = jnp.argmax(batch.value["feat"] @ self.w,
                           axis=-1).astype(jnp.int32)
        minute = batch.ts // TICKS_PER_MINUTE
        key = topic * 100_000 + minute          # "v_m" composite key
        return {"S2": EventBatch(sid=batch.sid, ts=batch.ts + 1, key=key,
                                 value={"topic": topic},
                                 valid=batch.valid)}


class MinuteCounter(SequentialUpdater):
    """U1: count events per <topic, minute>; when the minute rolls over,
    emit <topic_minute, count> into S3 (the paper emits after a minute —
    we emit on the first event of the next minute, same content)."""
    name = "U1"
    subscribes = ("S2",)
    in_value_spec = {"topic": ((), jnp.int32)}
    out_streams = {"S3": {"count": ((), jnp.int32)}}
    table_capacity = 4096
    max_run = 192

    def slate_spec(self):
        return {"count": ((), jnp.int32), "emitted": ((), jnp.int32)}

    def step(self, slate, ev):
        new_count = slate["count"] + 1
        minute_now = ev["ts"] // TICKS_PER_MINUTE
        key_minute = ev["key"] % 100_000
        closed = minute_now > key_minute        # this minute has passed
        do_emit = closed & (slate["emitted"] == 0)
        # re-key to the TOPIC: U2's slate holds the topic's history
        # across minutes (the paper's avg_count_{v_m} across days)
        out = {"S3": {"key": ev["key"] // 100_000,
                      "value": {"count": new_count},
                      "emit": do_emit}}
        return ({"count": new_count,
                 "emitted": jnp.where(do_emit, 1,
                                      slate["emitted"])}, out)


class HotTopicDetector(AssociativeUpdater):
    """U2: slate keeps total_count/periods per topic-minute-of-day;
    emits hot topics when count / avg > threshold."""
    name = "U2"
    subscribes = ("S3",)
    in_value_spec = {"count": ((), jnp.int32)}
    out_streams = {"hot": {"ratio_x100": ((), jnp.int32)}}
    table_capacity = 4096
    threshold = 2.0

    def slate_spec(self):
        return {"total": ((), jnp.float32), "periods": ((), jnp.int32)}

    def lift(self, batch):
        return {"total": batch.value["count"].astype(jnp.float32),
                "periods": jnp.ones_like(batch.key)}

    def combine(self, a, b):
        return {"total": a["total"] + b["total"],
                "periods": a["periods"] + b["periods"]}

    def merge(self, slate, delta):
        return {"total": slate["total"] + delta["total"],
                "periods": slate["periods"] + delta["periods"]}

    def emit(self, keys, old, new, ts):
        cur = new["total"] - old["total"]       # this period's count
        avg = jnp.where(old["periods"] > 0,
                        old["total"] / jnp.maximum(old["periods"], 1),
                        cur)
        ratio = cur / jnp.maximum(avg, 1e-6)
        hot = ratio > self.threshold
        return {"hot": EventBatch(
            sid=jnp.zeros_like(keys), ts=ts + 1, key=keys,
            value={"ratio_x100": (ratio * 100).astype(jnp.int32)},
            valid=hot)}


def main():
    rng = np.random.default_rng(0)
    topic_dirs = rng.normal(size=(N_TOPICS, FEAT)).astype(np.float32)
    m1 = TopicClassifierMapper(topic_dirs)
    wf = Workflow([m1, MinuteCounter(), HotTopicDetector()],
                  external_streams=("tweets",))
    eng = Engine(wf, EngineConfig(batch_size=2048, queue_capacity=8192))
    state = eng.init_state()
    hot_events = []
    N = 512
    for tick in range(40):
        minute = tick // TICKS_PER_MINUTE
        # minute 5+: topic burst — 60% of tweets about one topic
        if minute >= 5:
            burst = rng.random(N) < 0.6
            t_ids = np.where(burst, 3,
                             rng.integers(0, N_TOPICS, N))
        else:
            t_ids = rng.integers(0, N_TOPICS, N)
        feat = topic_dirs[t_ids] * 3 + rng.normal(
            size=(N, FEAT)).astype(np.float32)
        batch = EventBatch.of(
            key=rng.integers(0, 1 << 30, N).astype(np.int32),
            value={"feat": feat.astype(np.float32)},
            ts=np.full(N, tick, np.int32))
        state, outs = eng.step(state, {"tweets": batch})
        if "hot" in outs:
            hb = outs["hot"]
            for k, r in zip(np.asarray(hb.key)[np.asarray(hb.valid)],
                            np.asarray(hb.value["ratio_x100"])
                            [np.asarray(hb.valid)]):
                hot_events.append((int(k), tick, r / 100))
                print(f"tick {tick}: HOT topic={int(k)} "
                      f"ratio={r/100:.1f}x")

    assert hot_events, "the burst should surface a hot topic"
    from collections import Counter
    top = Counter(t for t, _, _ in hot_events).most_common(1)[0][0]
    assert top == 3, f"burst topic 3 should dominate, got {top}"
    print(f"\ndetected {len(hot_events)} hot <topic,minute> pairs; "
          f"stats: {eng.stats(state)['processed']}")


if __name__ == "__main__":
    main()
