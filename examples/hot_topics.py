"""Paper Example 2/5: detect hot topics on a tweet stream — declarative
builder edition.

Workflow (Figure 1c)::

  tweets --M1(classify into topic_minute)--> S2
  S2 --U1(count per topic_minute; emit count each minute)--> S3
  S3 --U2(compare to per-minute historical average; emit hot topics)--> S4

M1's "classifier" is a real (tiny) matched filter against learned topic
embeddings — the map function runs a model inside the stream, as
Kosmix's classifiers did.  All three operators are plain functions: M1
a traced mapper, U1 a sequential (order-sensitive) step function, U2 an
associative lift + emit pair.  ``U2`` subscribes to ``S3`` before its
producer is declared — forward stream references are how the builder
expresses arbitrary graph shapes (including cycles).

Run:  PYTHONPATH=src python examples/hot_topics.py
"""
import jax.numpy as jnp
import numpy as np

from repro import App, EventBatch, RuntimeConfig

N_TOPICS = 16
FEAT = 32
TICKS_PER_MINUTE = 4
HOT_THRESHOLD = 2.0


def build_app(topic_embeds) -> App:
    app = App("hot_topics")
    tweets = app.source("tweets", {"feat": ((FEAT,), jnp.float32)})
    w = jnp.asarray(topic_embeds.T)            # [FEAT, N_TOPICS]

    @app.mapper(tweets, out="S2", name="M1")
    def classify(batch):
        topic = jnp.argmax(batch.value["feat"] @ w,
                           axis=-1).astype(jnp.int32)
        minute = batch.ts // TICKS_PER_MINUTE
        key = topic * 100_000 + minute          # "v_m" composite key
        return EventBatch(sid=batch.sid, ts=batch.ts + 1, key=key,
                          value={"topic": topic}, valid=batch.valid)

    # U2 declared against "S3" before U1 (its producer) exists: forward
    # stream reference.  The lift/emit pair is the paper's
    # current-vs-historical-average comparison.
    def hot_emit(keys, old, new, ts):
        cur = new["total"] - old["total"]       # this period's count
        avg = jnp.where(old["periods"] > 0,
                        old["total"] / jnp.maximum(old["periods"], 1),
                        cur)
        ratio = cur / jnp.maximum(avg, 1e-6)
        return {"hot": EventBatch(
            sid=jnp.zeros_like(keys), ts=ts + 1, key=keys,
            value={"ratio_x100": (ratio * 100).astype(jnp.int32)},
            valid=ratio > HOT_THRESHOLD)}

    @app.updater("S3", name="U2",
                 slate={"total": ((), jnp.float32),
                        "periods": ((), jnp.int32)},
                 emit=hot_emit)
    def track(batch):
        return {"total": batch.value["count"].astype(jnp.float32),
                "periods": jnp.ones_like(batch.key)}

    @app.seq_updater("S2", name="U1", max_run=192,
                     slate={"count": ((), jnp.int32),
                            "emitted": ((), jnp.int32)})
    def minute_count(slate, ev):
        """Count events per <topic, minute>; on the first event of the
        next minute emit <topic, count> into S3 (re-keyed to the topic:
        U2's slate holds the topic's history across minutes)."""
        new_count = slate["count"] + 1
        minute_now = ev["ts"] // TICKS_PER_MINUTE
        key_minute = ev["key"] % 100_000
        closed = minute_now > key_minute        # this minute has passed
        do_emit = closed & (slate["emitted"] == 0)
        out = {"S3": {"key": ev["key"] // 100_000,
                      "value": {"count": new_count},
                      "emit": do_emit}}
        return ({"count": new_count,
                 "emitted": jnp.where(do_emit, 1, slate["emitted"])}, out)

    return app


def main():
    rng = np.random.default_rng(0)
    topic_dirs = rng.normal(size=(N_TOPICS, FEAT)).astype(np.float32)
    app = build_app(topic_dirs)
    app.start(RuntimeConfig(batch_size=2048, queue_capacity=8192,
                            chunk_size=1))

    hot_events = []
    N = 512

    def source_fn(tick, max_events):
        minute = tick // TICKS_PER_MINUTE
        # minute 5+: topic burst — 60% of tweets about one topic
        if minute >= 5:
            burst = rng.random(N) < 0.6
            t_ids = np.where(burst, 3, rng.integers(0, N_TOPICS, N))
        else:
            t_ids = rng.integers(0, N_TOPICS, N)
        feat = topic_dirs[t_ids] * 3 + rng.normal(
            size=(N, FEAT)).astype(np.float32)
        return {"tweets": EventBatch.of(
            key=rng.integers(0, 1 << 30, N).astype(np.int32),
            value={"feat": feat.astype(np.float32)},
            ts=np.full(N, tick, np.int32))}

    outs = app.run(source_fn, n_ticks=40)
    for tick, o in enumerate(outs):
        if "hot" not in o:
            continue
        hb = o["hot"]
        for k, r in zip(np.asarray(hb.key)[np.asarray(hb.valid)],
                        np.asarray(hb.value["ratio_x100"])
                        [np.asarray(hb.valid)]):
            hot_events.append((int(k), tick, r / 100))
            print(f"tick {tick}: HOT topic={int(k)} ratio={r/100:.1f}x")

    assert hot_events, "the burst should surface a hot topic"
    from collections import Counter
    top = Counter(t for t, _, _ in hot_events).most_common(1)[0][0]
    assert top == 3, f"burst topic 3 should dominate, got {top}"
    print(f"\ndetected {len(hot_events)} hot <topic,minute> pairs; "
          f"stats: {app.stats()['processed']}")
    app.close()


if __name__ == "__main__":
    main()
